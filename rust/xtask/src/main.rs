//! `cargo xtask` — the repo's offline static-analysis tool.
//!
//! Two subcommands share one token-level engine (`lexer`/`index`):
//!
//! * `cargo xtask lint` — the four PR-9 conventions, now matched on the
//!   token stream instead of raw lines (string literals, comments, and
//!   multiline call chains are no longer false-positive/negative
//!   classes):
//!   - **std-sync** — no `std::sync` outside `src/sync/`; everything
//!     else imports `crate::sync` so the loom build checks the same
//!     lock production runs.
//!   - **lock-unwrap** — no `.lock().unwrap()` / `.lock().expect(…)`;
//!     poison recovery via `crate::sync::lock_recover` is the serving
//!     core's contract.
//!   - **hash-iteration** — no iterating `HashMap`/`HashSet` bindings
//!     in the scoring hot paths (`src/hdc/`, `src/engine/backend.rs`);
//!     keyed lookup is fine, traversal is not.
//!   - **lock-order** — `LockRank` acquisitions within one function
//!     must follow the serve → filters → mem → adj → cache hierarchy;
//!     waive a drop-and-reacquire with `// lint: allow-lock-order` on
//!     the acquiring line.
//!
//! * `cargo xtask analyze [--format json]` — the four deeper analyses
//!   (HDR-PANIC, HDR-ALLOC, HDR-FLOAT, HDR-EPOCH) over a function index
//!   and a conservative intra-crate call graph, gated by the
//!   checked-in `rust/analyze-baseline.json`. See `ANALYSIS.md`.
//!
//! Deliberately dependency-free (no syn, no rustc plugin): it runs
//! offline, in milliseconds, and the rules key off token shapes —
//! imports, method-call spellings, rank literals — which the
//! hand-rolled lexer preserves exactly. `src/sync/` itself (which wraps
//! `std::sync` and deliberately tests ordering violations) and this
//! tool are exempt.

mod analyses;
mod diag;
mod index;
mod lexer;

use lexer::{Kind, Tok};
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    match args.next().as_deref() {
        Some("lint") => lint(),
        Some("analyze") => {
            let json = matches!(args.next().as_deref(), Some("--format"))
                && matches!(args.next().as_deref(), Some("json"));
            analyze(json)
        }
        _ => {
            eprintln!("usage: cargo xtask <lint | analyze [--format json]>");
            ExitCode::FAILURE
        }
    }
}

fn lint() -> ExitCode {
    let mut violations = Vec::new();
    let mut files = 0usize;
    for (rel, text) in collect_repo_files() {
        files += 1;
        violations.extend(check_file(&rel, &text));
    }
    for v in &violations {
        eprintln!("{v}");
    }
    if violations.is_empty() {
        println!("xtask lint: {files} files clean");
        ExitCode::SUCCESS
    } else {
        eprintln!("xtask lint: {} violation(s) in {files} files", violations.len());
        ExitCode::FAILURE
    }
}

/// The `analyze` input set: the crate's `src/` minus the `sync/` facade
/// (which wraps `std::sync` by design and is covered by loom, not by
/// these analyses).
fn analyze_files() -> Vec<(String, String)> {
    collect_repo_files()
        .into_iter()
        .filter(|(rel, _)| rel.starts_with("rust/src/") && !rel.starts_with("rust/src/sync/"))
        .collect()
}

fn baseline_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask lives one level under the rust crate")
        .join("analyze-baseline.json")
}

fn load_baseline() -> Result<Vec<diag::BaselineEntry>, String> {
    match fs::read_to_string(baseline_path()) {
        Ok(text) => diag::parse_baseline(&text),
        Err(_) => Ok(Vec::new()), // no baseline file: nothing grandfathered
    }
}

fn analyze(json: bool) -> ExitCode {
    let outcome = analyses::run(analyze_files());
    let base = match load_baseline() {
        Ok(b) => b,
        Err(e) => {
            eprintln!("xtask analyze: {e}");
            return ExitCode::FAILURE;
        }
    };
    let (fresh, grandfathered, stale) = diag::apply_baseline(outcome.diags, &base);
    if json {
        print!("{}", diag::to_json(&fresh, &grandfathered));
    } else {
        for d in &fresh {
            eprintln!("{d}\n");
        }
    }
    for (file, line) in &outcome.unused_waivers {
        eprintln!("warning: unused waiver at {file}:{line}");
    }
    for e in &stale {
        eprintln!(
            "error: stale baseline entry [{}] {} `{}` — the finding is gone; \
             shrink rust/analyze-baseline.json",
            e.code, e.file, e.function
        );
    }
    if fresh.is_empty() && stale.is_empty() {
        if !json {
            println!(
                "xtask analyze: clean ({} grandfathered, {} waiver(s) unused)",
                grandfathered.len(),
                outcome.unused_waivers.len()
            );
        }
        ExitCode::SUCCESS
    } else {
        if !json {
            eprintln!(
                "xtask analyze: {} new finding(s), {} stale baseline entr(ies)",
                fresh.len(),
                stale.len()
            );
        }
        ExitCode::FAILURE
    }
}

/// Every `.rs` file the rules apply to, as `(repo-relative path, text)`.
/// Scanned roots: the crate's `src`/`tests`/`benches` and the repo-root
/// `examples/` (which the crate builds via explicit `[[example]]`
/// paths). `src/sync/` files are collected — [`check_file`] exempts
/// them — but `xtask/` itself is not.
fn collect_repo_files() -> Vec<(String, String)> {
    let rust_dir = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask lives one level under the rust crate")
        .to_path_buf();
    let repo_root = rust_dir.parent().expect("rust crate lives one level under the repo root");
    let roots = [
        rust_dir.join("src"),
        rust_dir.join("tests"),
        rust_dir.join("benches"),
        repo_root.join("examples"),
    ];
    let mut paths = Vec::new();
    for root in &roots {
        rs_files(root, &mut paths);
    }
    paths
        .into_iter()
        .filter_map(|p| {
            let rel = p
                .strip_prefix(repo_root)
                .expect("scanned file under the repo root")
                .to_string_lossy()
                .replace('\\', "/");
            fs::read_to_string(&p).ok().map(|text| (rel, text))
        })
        .collect()
}

fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    let mut paths: Vec<PathBuf> = entries.flatten().map(|e| e.path()).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            rs_files(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

struct Violation {
    rel: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.rel, self.line, self.rule, self.msg)
    }
}

const RANKS: [&str; 5] = ["Serve", "Filters", "Mem", "Adj", "Cache"];

fn is_punct(t: &[Tok], p: usize, s: &str) -> bool {
    t.get(p).is_some_and(|x| x.kind == Kind::Punct && x.text == s)
}

fn is_ident(t: &[Tok], p: usize, s: &str) -> bool {
    t.get(p).is_some_and(|x| x.kind == Kind::Ident && x.text == s)
}

fn is_hash_type_name(s: &str) -> bool {
    s.ends_with("HashMap") || s.ends_with("HashSet")
}

/// Run every lint rule over one file. `rel` is the repo-relative path
/// with forward slashes (e.g. `rust/src/engine/backend.rs`); rules key
/// off it for exemptions and hot-path scoping.
fn check_file(rel: &str, text: &str) -> Vec<Violation> {
    let mut out = Vec::new();
    if rel.starts_with("rust/src/sync/") {
        // the facade wraps std::sync by design, and its tests
        // deliberately violate the lock order to pin the runtime assert
        return out;
    }
    let lx = lexer::lex(text);
    let t = &lx.toks;
    let hot_path = rel.starts_with("rust/src/hdc/") || rel == "rust/src/engine/backend.rs";
    let hash_names: Vec<String> = if hot_path { hash_bindings(t) } else { Vec::new() };
    // (rank index, rank name, line) of the last ranked acquisition in
    // the current function
    let mut last_rank: Option<(usize, &'static str, usize)> = None;
    for p in 0..t.len() {
        if is_ident(t, p, "std") && is_punct(t, p + 1, ":") && is_punct(t, p + 2, ":")
            && is_ident(t, p + 3, "sync")
        {
            out.push(Violation {
                rel: rel.to_string(),
                line: t[p].line,
                rule: "std-sync",
                msg: "imports std::sync directly — use the crate::sync facade so the loom \
                      build checks the same lock production runs"
                    .to_string(),
            });
        }
        if is_punct(t, p, ".") && is_ident(t, p + 1, "lock") && is_punct(t, p + 2, "(")
            && is_punct(t, p + 3, ")")
            && is_punct(t, p + 4, ".")
            && (is_ident(t, p + 5, "unwrap") || is_ident(t, p + 5, "expect"))
            && is_punct(t, p + 6, "(")
        {
            out.push(Violation {
                rel: rel.to_string(),
                line: t[p].line,
                rule: "lock-unwrap",
                msg: "panics on a poisoned lock — use crate::sync::lock_recover; poison \
                      recovery is the serving core's contract"
                    .to_string(),
            });
        }
        if hot_path {
            if let Some(name) = iterated_hash_name(t, p, &hash_names) {
                out.push(Violation {
                    rel: rel.to_string(),
                    line: t[p].line,
                    rule: "hash-iteration",
                    msg: format!(
                        "iterates the hash collection `{name}` in a scoring hot path — \
                         iteration order is nondeterministic and rankings must be \
                         deterministic; use keyed lookup or a sorted/dense structure"
                    ),
                });
            }
        }
        if is_ident(t, p, "fn") {
            last_rank = None;
        }
        if is_ident(t, p, "LockRank") && is_punct(t, p + 1, ":") && is_punct(t, p + 2, ":") {
            if let Some(name) = t.get(p + 3).filter(|x| x.kind == Kind::Ident) {
                if let Some(rank) = RANKS.iter().position(|&r| r == name.text) {
                    let line = t[p].line;
                    if let Some((prev, prev_name, prev_line)) = last_rank {
                        let waived = lx
                            .comment_on(line)
                            .is_some_and(|c| c.contains("lint: allow-lock-order"));
                        if rank < prev && !waived {
                            out.push(Violation {
                                rel: rel.to_string(),
                                line,
                                rule: "lock-order",
                                msg: format!(
                                    "acquires {} after {} (line {prev_line}), against the \
                                     serve → filters → mem → adj → cache hierarchy \
                                     (CONCURRENCY.md); waive a drop-and-reacquire with \
                                     `// lint: allow-lock-order`",
                                    RANKS[rank], prev_name
                                ),
                            });
                        }
                    }
                    last_rank = Some((rank, RANKS[rank], line));
                }
            }
        }
    }
    out
}

/// Identifiers bound (by `let`) or declared (as a field / parameter) with
/// a `HashMap`/`HashSet` type or initializer anywhere in the file.
fn hash_bindings(t: &[Tok]) -> Vec<String> {
    let mut names: Vec<String> = Vec::new();
    let mut p = 0usize;
    while p < t.len() {
        // let [mut] name … ;  — type or initializer names a hash type
        if is_ident(t, p, "let") {
            let mut q = p + 1;
            if is_ident(t, q, "mut") {
                q += 1;
            }
            if t.get(q).is_some_and(|x| x.kind == Kind::Ident) {
                let name = t[q].text.clone();
                let mut depth = 0i32;
                let mut r = q + 1;
                let mut found = false;
                while r < t.len() {
                    let s = &t[r];
                    if s.kind == Kind::Punct {
                        match s.text.as_str() {
                            "(" | "[" | "{" => depth += 1,
                            ")" | "]" | "}" => {
                                if depth == 0 {
                                    break;
                                }
                                depth -= 1;
                            }
                            ";" if depth == 0 => break,
                            _ => {}
                        }
                    } else if s.kind == Kind::Ident && is_hash_type_name(&s.text) {
                        found = true;
                    }
                    r += 1;
                }
                if found && !names.contains(&name) {
                    names.push(name);
                }
            }
            p += 1;
            continue;
        }
        // name: Type — struct field or parameter typed as a hash type
        // (`:` but not `::`); the type ends at `,` `;` `=` `{` `)` at
        // angle-bracket depth 0
        if t[p].kind == Kind::Ident
            && is_punct(t, p + 1, ":")
            && !is_punct(t, p + 2, ":")
        {
            let name = t[p].text.clone();
            let mut angle = 0i32;
            let mut r = p + 2;
            let mut found = false;
            while r < t.len() {
                let s = &t[r];
                if s.kind == Kind::Punct {
                    match s.text.as_str() {
                        "<" => angle += 1,
                        ">" => angle -= 1,
                        "," | ";" | "=" | "{" | ")" if angle <= 0 => break,
                        _ => {}
                    }
                } else if s.kind == Kind::Ident && is_hash_type_name(&s.text) {
                    found = true;
                } else if s.kind != Kind::Ident && s.kind != Kind::Life {
                    // numbers/strings end a type position
                    break;
                }
                r += 1;
            }
            if found && !names.contains(&name) {
                names.push(name);
            }
        }
        p += 1;
    }
    names
}

const HASH_ITER_METHODS: [&str; 8] =
    ["iter", "iter_mut", "keys", "values", "values_mut", "drain", "retain", "into_iter"];

/// Does position `p` traverse one of `names` — by iterator method or
/// `for … in`? Keyed access (`get`/`insert`/`contains_key`/…) is allowed.
fn iterated_hash_name(t: &[Tok], p: usize, names: &[String]) -> Option<String> {
    // name.iter() and friends
    if t[p].kind == Kind::Ident && names.contains(&t[p].text) {
        if is_punct(t, p + 1, ".")
            && t.get(p + 2)
                .is_some_and(|x| {
                    x.kind == Kind::Ident && HASH_ITER_METHODS.contains(&x.text.as_str())
                })
            && is_punct(t, p + 3, "(")
        {
            return Some(t[p].text.clone());
        }
    }
    // for … in [&][mut] name {
    if is_ident(t, p, "for") {
        let mut q = p + 1;
        let mut depth = 0i32;
        while q < t.len() {
            let s = &t[q];
            if s.kind == Kind::Punct {
                match s.text.as_str() {
                    "(" | "[" => depth += 1,
                    ")" | "]" => depth -= 1,
                    "{" => return None, // loop body reached: no `in`
                    _ => {}
                }
            } else if s.kind == Kind::Ident && s.text == "in" && depth == 0 {
                break;
            }
            q += 1;
        }
        let mut r = q + 1;
        while is_punct(t, r, "&") {
            r += 1;
        }
        if is_ident(t, r, "mut") {
            r += 1;
        }
        if t.get(r).is_some_and(|x| x.kind == Kind::Ident && names.contains(&x.text))
            && is_punct(t, r + 1, "{")
        {
            return Some(t[r].text.clone());
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(rel: &str, text: &str) -> Vec<&'static str> {
        check_file(rel, text).into_iter().map(|v| v.rule).collect()
    }

    // -- std-sync ----------------------------------------------------------

    #[test]
    fn seeded_std_sync_import_fails_the_lint() {
        let fixture = "use std::sync::Mutex;\n";
        assert_eq!(rules("rust/src/engine/mod.rs", fixture), ["std-sync"]);
    }

    #[test]
    fn the_sync_facade_itself_is_exempt() {
        let fixture = "pub use std::sync::{Arc, Mutex};\n";
        assert!(rules("rust/src/sync/mod.rs", fixture).is_empty());
    }

    #[test]
    fn prose_about_std_sync_in_comments_is_not_a_violation() {
        let fixture = "//! re-exports `std::sync` under the default build\n\
                       use crate::sync::Mutex;\n";
        assert!(rules("rust/src/engine/mod.rs", fixture).is_empty());
    }

    #[test]
    fn std_sync_inside_a_string_literal_is_not_a_violation() {
        // the old text scan could not tell literals from code
        let fixture = "let msg = \"std::sync is forbidden\";\n";
        assert!(rules("rust/src/engine/mod.rs", fixture).is_empty());
    }

    // -- lock-unwrap -------------------------------------------------------

    #[test]
    fn seeded_lock_unwrap_fails_the_lint() {
        let fixture = "let g = self.serve.lock().unwrap();\n";
        assert_eq!(rules("rust/src/engine/mod.rs", fixture), ["lock-unwrap"]);
        let fixture = "let g = self.serve.lock().expect(\"poisoned\");\n";
        assert_eq!(rules("rust/src/engine/mod.rs", fixture), ["lock-unwrap"]);
    }

    #[test]
    fn lock_recover_is_the_blessed_spelling() {
        let fixture = "let g = lock_recover(&self.serve);\n\
                       let h = m.lock().unwrap_or_else(PoisonError::into_inner);\n";
        assert!(rules("rust/src/engine/mod.rs", fixture).is_empty());
    }

    #[test]
    fn multiline_lock_unwrap_chains_are_caught() {
        // rustfmt loves to split long chains — the old line scan missed these
        let fixture = "let g = self\n    .serve\n    .lock()\n    .unwrap();\n";
        assert_eq!(rules("rust/src/engine/mod.rs", fixture), ["lock-unwrap"]);
    }

    // -- hash-iteration ----------------------------------------------------

    #[test]
    fn seeded_hash_iteration_in_a_hot_path_fails_the_lint() {
        let fixture = "let mut acc: FxHashMap<u32, f32> = FxHashMap::default();\n\
                       for (k, v) in &acc {\n    scores[*k as usize] += v;\n}\n";
        assert_eq!(rules("rust/src/hdc/kernels.rs", fixture), ["hash-iteration"]);
    }

    #[test]
    fn hash_method_iteration_in_a_hot_path_fails_the_lint() {
        let fixture = "rows: crate::util::FxHashMap<u32, Vec<f32>>,\n\
                       let total: f32 = self.rows.values().map(|r| r[0]).sum();\n";
        assert_eq!(rules("rust/src/engine/backend.rs", fixture), ["hash-iteration"]);
    }

    #[test]
    fn keyed_lookup_in_a_hot_path_is_allowed() {
        let fixture = "rows: crate::util::FxHashMap<u32, Vec<f32>>,\n\
                       if self.rows.contains_key(&j) {\n    return self.rows.get(&j);\n}\n\
                       self.rows.entry(j).or_insert(rowq)\n";
        assert!(rules("rust/src/engine/backend.rs", fixture).is_empty());
    }

    #[test]
    fn hash_iteration_outside_hot_paths_is_allowed() {
        let fixture = "let mut acc: FxHashMap<u32, f32> = FxHashMap::default();\n\
                       for (k, v) in &acc {\n}\n";
        assert!(rules("rust/src/kg/mod.rs", fixture).is_empty());
    }

    #[test]
    fn identifier_matching_respects_word_boundaries() {
        // `borrows.iter()` must not match the binding `rows`
        let fixture = "rows: crate::util::FxHashMap<u32, Vec<f32>>,\n\
                       let n = borrows.iter().count();\n";
        assert!(rules("rust/src/engine/backend.rs", fixture).is_empty());
    }

    // -- lock-order --------------------------------------------------------

    #[test]
    fn seeded_out_of_order_acquisition_fails_the_lint() {
        let fixture = "fn broken(&self) {\n\
                           let adj = lock_recover_ranked(&self.adj, LockRank::Adj);\n\
                           let mem = lock_recover_ranked(&self.mem, LockRank::Mem);\n\
                       }\n";
        assert_eq!(rules("rust/src/engine/mod.rs", fixture), ["lock-order"]);
    }

    #[test]
    fn hierarchy_order_acquisition_passes() {
        let fixture = "fn fine(&self) {\n\
                           let mem = lock_recover_ranked(&self.mem, LockRank::Mem);\n\
                           let adj = lock_recover_ranked(&self.adj, LockRank::Adj);\n\
                       }\n";
        assert!(rules("rust/src/engine/mod.rs", fixture).is_empty());
    }

    #[test]
    fn equal_rank_reacquisition_passes() {
        // drop-and-retake of the same lock (the serve_via_cache seam)
        let fixture = "fn probe_then_insert(cache: &Mutex<ServingCache>) {\n\
                           drop(lock_recover_ranked(cache, LockRank::Cache));\n\
                           drop(lock_recover_ranked(cache, LockRank::Cache));\n\
                       }\n";
        assert!(rules("rust/src/engine/protocol.rs", fixture).is_empty());
    }

    #[test]
    fn function_boundaries_reset_the_rank_sequence() {
        let fixture = "fn high(&self) {\n\
                           let c = lock_recover_ranked(&self.cache, LockRank::Cache);\n\
                       }\n\
                       fn low(&self) {\n\
                           let s = lock_recover_ranked(&self.serve, LockRank::Serve);\n\
                       }\n";
        assert!(rules("rust/src/engine/mod.rs", fixture).is_empty());
    }

    #[test]
    fn allow_marker_waives_a_drop_and_reacquire() {
        let fixture = "fn waived(&self) {\n\
                           drop(lock_recover_ranked(&self.adj, LockRank::Adj));\n\
                           let m = lock_recover_ranked(&self.mem, LockRank::Mem); \
                       // lint: allow-lock-order\n\
                       }\n";
        assert!(rules("rust/src/engine/mod.rs", fixture).is_empty());
    }

    // -- analyze: shared fixture plumbing ----------------------------------

    fn run_analyses(files: &[(&str, &str)]) -> Vec<(String, String)> {
        let owned = files.iter().map(|&(a, b)| (a.to_string(), b.to_string())).collect();
        analyses::run(owned)
            .diags
            .into_iter()
            .map(|d| (d.code, d.function))
            .collect()
    }

    // -- analyze: HDR-PANIC ------------------------------------------------

    #[test]
    fn seeded_unwrap_reachable_from_serving_fires_hdr_panic() {
        let src = "pub fn submit(&self) { helper(); }\n\
                   fn helper(&self) { self.q.front().unwrap(); }\n\
                   fn offline(&self) { self.q.front().unwrap(); }\n";
        let got = run_analyses(&[("rust/src/engine/mod.rs", src)]);
        assert_eq!(got, [("HDR-PANIC".to_string(), "helper".to_string())]);
    }

    #[test]
    fn panics_behind_error_returns_are_silent() {
        let src = "pub fn submit(&self) -> Option<u32> { helper() }\n\
                   fn helper(&self) -> Option<u32> { self.q.front().copied() }\n";
        assert!(run_analyses(&[("rust/src/engine/mod.rs", src)]).is_empty());
    }

    #[test]
    fn control_plane_indexing_fires_and_get_is_blessed() {
        let bad = "pub fn submit(&self, batch: &[u32], i: usize) -> u32 { batch[i] }\n";
        let got = run_analyses(&[("rust/src/engine/mod.rs", bad)]);
        assert_eq!(got, [("HDR-PANIC".to_string(), "submit".to_string())]);
        let good =
            "pub fn submit(&self, batch: &[u32], i: usize) -> u32 { \
             batch.get(i).copied().unwrap_or(0) }\n";
        assert!(run_analyses(&[("rust/src/engine/mod.rs", good)]).is_empty());
    }

    #[test]
    fn data_plane_indexing_is_not_flagged() {
        // dense matrix offsets are the kernels' core idiom
        let src = "pub fn rank_requests(mv: &[f32], j: usize) -> f32 { mv[j] }\n";
        assert!(run_analyses(&[("rust/src/engine/backend.rs", src)]).is_empty());
    }

    #[test]
    fn test_functions_are_outside_the_reachable_set() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn submit() { x.unwrap(); }\n}\n";
        assert!(run_analyses(&[("rust/src/engine/mod.rs", src)]).is_empty());
    }

    // -- analyze: HDR-ALLOC ------------------------------------------------

    #[test]
    fn seeded_allocation_in_a_hot_path_fn_fires_hdr_alloc() {
        let src = "#[crate::hdr_hot_path]\n\
                   fn bind_rows(xs: &[f32]) -> f32 { let v: Vec<f32> = xs.iter().collect(); v[0] }\n";
        let got = run_analyses(&[("rust/src/hdc/kernels.rs", src)]);
        assert_eq!(got, [("HDR-ALLOC".to_string(), "bind_rows".to_string())]);
    }

    #[test]
    fn preallocated_buffers_in_a_hot_path_fn_are_silent() {
        let src = "#[crate::hdr_hot_path]\n\
                   fn bind_rows(xs: &[f32], out: &mut [f32]) { out[0] = xs[0]; }\n";
        assert!(run_analyses(&[("rust/src/hdc/kernels.rs", src)]).is_empty());
    }

    #[test]
    fn the_hot_path_manifest_covers_unannotated_fns() {
        // l1_distance is manifest-listed: no attribute needed
        let src = "pub fn l1_distance(a: &[f32]) -> Vec<f32> { a.to_vec() }\n";
        let got = run_analyses(&[("rust/src/hdc/ops.rs", src)]);
        assert_eq!(got, [("HDR-ALLOC".to_string(), "l1_distance".to_string())]);
    }

    #[test]
    fn allocation_outside_annotated_fns_is_silent() {
        let src = "fn setup(xs: &[f32]) -> Vec<f32> { xs.to_vec() }\n";
        assert!(run_analyses(&[("rust/src/hdc/kernels.rs", src)]).is_empty());
    }

    // -- analyze: HDR-FLOAT ------------------------------------------------

    #[test]
    fn seeded_iterator_sum_in_the_float_scope_fires_hdr_float() {
        let src = "pub fn l1(a: &[f32], b: &[f32]) -> f32 {\n\
                       a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()\n\
                   }\n";
        let got = run_analyses(&[("rust/src/hdc/ops.rs", src)]);
        assert_eq!(got, [("HDR-FLOAT".to_string(), "l1".to_string())]);
    }

    #[test]
    fn blessed_blocked_accumulators_are_silent() {
        let src = "pub fn l1_blocked(a: &[f32], b: &[f32]) -> f32 {\n\
                       a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()\n\
                   }\n";
        assert!(run_analyses(&[("rust/src/hdc/ops.rs", src)]).is_empty());
    }

    #[test]
    fn sums_outside_the_float_scope_are_silent() {
        let src = "pub fn total(xs: &[usize]) -> usize { xs.iter().sum() }\n";
        assert!(run_analyses(&[("rust/src/kg/mod.rs", src)]).is_empty());
    }

    // -- analyze: HDR-EPOCH ------------------------------------------------

    #[test]
    fn seeded_insert_without_begin_fires_hdr_epoch() {
        let src = "fn fill(cache: &M, k: u64, v: u32) {\n\
                       let mut c = lock_recover_ranked(cache, LockRank::Cache);\n\
                       c.insert(k, v);\n\
                   }\n";
        let got = run_analyses(&[("rust/src/engine/protocol.rs", src)]);
        assert_eq!(got, [("HDR-EPOCH".to_string(), "fill".to_string())]);
    }

    #[test]
    fn begin_dominating_the_insert_is_silent() {
        let src = "fn fill(cache: &M, epoch: u64, k: u64, v: u32) {\n\
                       let mut c = lock_recover_ranked(cache, LockRank::Cache);\n\
                       if c.begin(epoch) {\n        c.insert(k, v);\n    }\n\
                   }\n";
        assert!(run_analyses(&[("rust/src/engine/protocol.rs", src)]).is_empty());
    }

    #[test]
    fn bare_mem_snapshot_on_the_serving_path_fires_hdr_epoch() {
        let src = "pub fn rank_requests(&self) { let mv = self.mem_snapshot(); }\n";
        let got = run_analyses(&[("rust/src/engine/mod.rs", src)]);
        assert_eq!(got, [("HDR-EPOCH".to_string(), "rank_requests".to_string())]);
    }

    #[test]
    fn epoch_carrying_snapshot_reads_are_silent() {
        let src =
            "pub fn rank_requests(&self) { let (mv, ep) = self.mem_snapshot_with_epoch(); }\n";
        assert!(run_analyses(&[("rust/src/engine/mod.rs", src)]).is_empty());
    }

    // -- analyze: waivers --------------------------------------------------

    #[test]
    fn a_reasoned_waiver_suppresses_the_finding() {
        let src = "pub fn submit(&self) {\n\
                       // analyze: allow(HDR-PANIC) deliberate re-raise of a quarantined panic\n\
                       self.q.front().unwrap();\n\
                   }\n";
        assert!(run_analyses(&[("rust/src/engine/mod.rs", src)]).is_empty());
    }

    #[test]
    fn a_waiver_without_a_reason_is_itself_a_finding() {
        let src = "pub fn submit(&self) {\n\
                       // analyze: allow(HDR-PANIC)\n\
                       self.q.front().unwrap();\n\
                   }\n";
        let got = run_analyses(&[("rust/src/engine/mod.rs", src)]);
        assert_eq!(got, [("HDR-WAIVER".to_string(), "submit".to_string())]);
    }

    #[test]
    fn a_waiver_for_the_wrong_code_does_not_suppress() {
        let src = "pub fn submit(&self) {\n\
                       // analyze: allow(HDR-FLOAT) wrong code entirely\n\
                       self.q.front().unwrap();\n\
                   }\n";
        let got = run_analyses(&[("rust/src/engine/mod.rs", src)]);
        assert_eq!(got, [("HDR-PANIC".to_string(), "submit".to_string())]);
    }

    #[test]
    fn unused_waivers_are_reported() {
        let src = "// analyze: allow(HDR-PANIC) nothing here needs this\n\
                   pub fn quiet() {}\n";
        let outcome =
            analyses::run(vec![("rust/src/engine/mod.rs".to_string(), src.to_string())]);
        assert!(outcome.diags.is_empty());
        assert_eq!(outcome.unused_waivers, [("rust/src/engine/mod.rs".to_string(), 1)]);
    }

    // -- analyze: baseline + JSON ------------------------------------------

    #[test]
    fn baseline_entries_suppress_known_findings_but_stale_entries_fail() {
        let src = "pub fn submit(&self) { self.q.front().unwrap(); }\n";
        let outcome =
            analyses::run(vec![("rust/src/engine/mod.rs".to_string(), src.to_string())]);
        let base = vec![
            diag::BaselineEntry {
                code: "HDR-PANIC".to_string(),
                file: "rust/src/engine/mod.rs".to_string(),
                function: "submit".to_string(),
            },
            diag::BaselineEntry {
                code: "HDR-PANIC".to_string(),
                file: "rust/src/engine/gone.rs".to_string(),
                function: "ghost".to_string(),
            },
        ];
        let (fresh, grandfathered, stale) = diag::apply_baseline(outcome.diags, &base);
        assert!(fresh.is_empty(), "baselined finding must not gate");
        assert_eq!(grandfathered.len(), 1);
        assert_eq!(stale.len(), 1, "the baseline may only shrink");
        assert_eq!(stale[0].function, "ghost");
    }

    #[test]
    fn json_output_golden() {
        let d = diag::Diagnostic {
            code: "HDR-PANIC".to_string(),
            file: "rust/src/engine/mod.rs".to_string(),
            line: 42,
            function: "lead".to_string(),
            message: "`.unwrap()` on the serving path".to_string(),
            note: "reachable from serving: submit → lead".to_string(),
        };
        let expected = "[\n  {\"code\":\"HDR-PANIC\",\
                        \"file\":\"rust/src/engine/mod.rs\",\
                        \"line\":42,\
                        \"function\":\"lead\",\
                        \"message\":\"`.unwrap()` on the serving path\",\
                        \"note\":\"reachable from serving: submit → lead\",\
                        \"baselined\":false}\n]\n";
        assert_eq!(diag::to_json(&[d], &[]), expected);
        assert_eq!(diag::to_json(&[], &[]), "[]\n");
    }

    // -- the real tree -----------------------------------------------------

    /// The production tree must be clean: this is the same scan `make ci`
    /// runs, so a regression fails both the lint step and the test suite.
    #[test]
    fn the_checked_in_tree_is_clean() {
        let mut violations = Vec::new();
        let mut files = 0;
        for (rel, text) in collect_repo_files() {
            files += 1;
            violations.extend(check_file(&rel, &text));
        }
        assert!(files > 30, "scan found only {files} files — roots misconfigured?");
        let rendered: Vec<String> = violations.iter().map(|v| v.to_string()).collect();
        assert!(rendered.is_empty(), "lint violations in the tree:\n{}", rendered.join("\n"));
    }

    /// Same gate as `cargo xtask analyze`: every finding is fixed, waived
    /// with a reason, or grandfathered in the baseline; no waiver is
    /// unused; no baseline entry is stale.
    #[test]
    fn the_checked_in_tree_is_analyze_clean() {
        let files = analyze_files();
        assert!(files.len() > 10, "analyze scan found only {} files", files.len());
        let outcome = analyses::run(files);
        let base = load_baseline().expect("baseline parses");
        let (fresh, _grandfathered, stale) = diag::apply_baseline(outcome.diags, &base);
        let rendered: Vec<String> = fresh.iter().map(|d| d.to_string()).collect();
        assert!(rendered.is_empty(), "analyze findings in the tree:\n{}", rendered.join("\n"));
        assert!(stale.is_empty(), "stale baseline entries: {stale:?}");
        assert!(
            outcome.unused_waivers.is_empty(),
            "unused waivers: {:?}",
            outcome.unused_waivers
        );
    }
}
