//! `cargo xtask lint` — the repo's concurrency lint pass.
//!
//! Four text-level rules enforce the conventions that keep the serving
//! core model-checkable (`CONCURRENCY.md`, `src/sync/`):
//!
//! * **std-sync** — no `std::sync` imports outside `src/sync/`. Every
//!   consumer must go through the `crate::sync` facade, or the loom
//!   build (`make loom`) silently checks a different lock than
//!   production runs.
//! * **lock-unwrap** — no `.lock().unwrap()` / `.lock().expect(...)`.
//!   Poison recovery via `crate::sync::lock_recover` is the serving
//!   core's contract: one panicking batch leader must not wedge every
//!   subsequent submit behind a `PoisonError`.
//! * **hash-iteration** — no iteration over `HashMap`/`HashSet`
//!   bindings in the scoring hot paths (`src/hdc/`,
//!   `src/engine/backend.rs`). Hash iteration order is
//!   nondeterministic, and rankings are specified to be deterministic
//!   across backends; keyed lookup is fine, traversal is not.
//! * **lock-order** — within one function, `LockRank` acquisitions
//!   must not go down the `serve → filters → mem → adj → cache`
//!   hierarchy. This is the static mirror of the debug-build assertion
//!   in `crate::sync::lock_recover_ranked`; a legitimate
//!   drop-and-reacquire that the text scan cannot see can be waived
//!   with `// lint: allow-lock-order` on the acquiring line.
//!
//! The pass is deliberately textual (no syn, no rustc plugin): it runs
//! offline, in milliseconds, with zero dependencies, and the rules are
//! about *names on lines* — imports, method-call spellings, rank
//! literals — which survive a text scan fine. Line comments are
//! stripped before matching so prose about `std::sync` doesn't trip it;
//! `src/sync/` itself (which wraps std and deliberately tests ordering
//! violations) and this tool (whose rule table spells the forbidden
//! patterns) are exempt.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    match args.next().as_deref() {
        Some("lint") => lint(),
        _ => {
            eprintln!("usage: cargo xtask lint");
            ExitCode::FAILURE
        }
    }
}

fn lint() -> ExitCode {
    let mut violations = Vec::new();
    let mut files = 0usize;
    for (rel, text) in collect_repo_files() {
        files += 1;
        violations.extend(check_file(&rel, &text));
    }
    for v in &violations {
        eprintln!("{v}");
    }
    if violations.is_empty() {
        println!("xtask lint: {files} files clean");
        ExitCode::SUCCESS
    } else {
        eprintln!("xtask lint: {} violation(s) in {files} files", violations.len());
        ExitCode::FAILURE
    }
}

/// Every `.rs` file the rules apply to, as `(repo-relative path, text)`.
/// Scanned roots: the crate's `src`/`tests`/`benches` and the repo-root
/// `examples/` (which the crate builds via explicit `[[example]]`
/// paths). `src/sync/` files are collected — [`check_file`] exempts
/// them — but `xtask/` itself is not.
fn collect_repo_files() -> Vec<(String, String)> {
    let rust_dir = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask lives one level under the rust crate")
        .to_path_buf();
    let repo_root = rust_dir.parent().expect("rust crate lives one level under the repo root");
    let roots = [
        rust_dir.join("src"),
        rust_dir.join("tests"),
        rust_dir.join("benches"),
        repo_root.join("examples"),
    ];
    let mut paths = Vec::new();
    for root in &roots {
        rs_files(root, &mut paths);
    }
    paths
        .into_iter()
        .filter_map(|p| {
            let rel = p
                .strip_prefix(repo_root)
                .expect("scanned file under the repo root")
                .to_string_lossy()
                .replace('\\', "/");
            fs::read_to_string(&p).ok().map(|text| (rel, text))
        })
        .collect()
}

fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    let mut paths: Vec<PathBuf> = entries.flatten().map(|e| e.path()).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            rs_files(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

struct Violation {
    rel: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.rel, self.line, self.rule, self.msg)
    }
}

const RANKS: [&str; 5] = ["Serve", "Filters", "Mem", "Adj", "Cache"];

/// Run every rule over one file. `rel` is the repo-relative path with
/// forward slashes (e.g. `rust/src/engine/backend.rs`); rules key off it
/// for exemptions and hot-path scoping.
fn check_file(rel: &str, text: &str) -> Vec<Violation> {
    let mut out = Vec::new();
    if rel.starts_with("rust/src/sync/") {
        // the facade wraps std::sync by design, and its tests
        // deliberately violate the lock order to pin the runtime assert
        return out;
    }
    let hot_path = rel.starts_with("rust/src/hdc/") || rel == "rust/src/engine/backend.rs";
    let mut hash_names: Vec<String> = Vec::new();
    if hot_path {
        for line in text.lines() {
            if let Some(name) = hash_binding_name(strip_comment(line)) {
                if !hash_names.contains(&name) {
                    hash_names.push(name);
                }
            }
        }
    }
    // (rank index, rank name, line) of the last ranked acquisition in
    // the current function
    let mut last_rank: Option<(usize, &'static str, usize)> = None;
    for (i, raw) in text.lines().enumerate() {
        let n = i + 1;
        let line = strip_comment(raw);
        if line.contains("std::sync") {
            out.push(Violation {
                rel: rel.to_string(),
                line: n,
                rule: "std-sync",
                msg: "imports std::sync directly — use the crate::sync facade so the loom \
                      build checks the same lock production runs"
                    .to_string(),
            });
        }
        for pat in [".lock().unwrap()", ".lock().expect("] {
            if line.contains(pat) {
                out.push(Violation {
                    rel: rel.to_string(),
                    line: n,
                    rule: "lock-unwrap",
                    msg: "panics on a poisoned lock — use crate::sync::lock_recover; poison \
                          recovery is the serving core's contract"
                        .to_string(),
                });
            }
        }
        if hot_path {
            for name in &hash_names {
                if iterates_hash(line, name) {
                    out.push(Violation {
                        rel: rel.to_string(),
                        line: n,
                        rule: "hash-iteration",
                        msg: format!(
                            "iterates the hash collection `{name}` in a scoring hot path — \
                             iteration order is nondeterministic and rankings must be \
                             deterministic; use keyed lookup or a sorted/dense structure"
                        ),
                    });
                }
            }
        }
        if find_word(line, "fn").is_some() {
            last_rank = None;
        }
        let mut rest = line;
        while let Some(p) = rest.find("LockRank::") {
            rest = &rest[p + "LockRank::".len()..];
            let ident: String = rest.chars().take_while(|&c| is_ident_char(c)).collect();
            if let Some(rank) = RANKS.iter().position(|&r| r == ident) {
                if let Some((prev, prev_name, prev_line)) = last_rank {
                    if rank < prev && !raw.contains("lint: allow-lock-order") {
                        out.push(Violation {
                            rel: rel.to_string(),
                            line: n,
                            rule: "lock-order",
                            msg: format!(
                                "acquires {} after {} (line {prev_line}), against the \
                                 serve → filters → mem → adj → cache hierarchy \
                                 (CONCURRENCY.md); waive a drop-and-reacquire with \
                                 `// lint: allow-lock-order`",
                                RANKS[rank], prev_name
                            ),
                        });
                    }
                }
                last_rank = Some((rank, RANKS[rank], n));
            }
        }
    }
    out
}

/// Truncate a line at its `//` comment. Naive about `//` inside string
/// literals, which can only hide text from the rules (a false negative
/// on a line that embeds a URL), never invent a violation.
fn strip_comment(line: &str) -> &str {
    match line.find("//") {
        Some(i) => &line[..i],
        None => line,
    }
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// First occurrence of `needle` in `hay` delimited by non-identifier
/// characters on both sides.
fn find_word(hay: &str, needle: &str) -> Option<usize> {
    let mut start = 0;
    while let Some(pos) = hay[start..].find(needle) {
        let i = start + pos;
        let before_ok = !hay[..i].chars().next_back().is_some_and(is_ident_char);
        let after_ok = !hay[i + needle.len()..].chars().next().is_some_and(is_ident_char);
        if before_ok && after_ok {
            return Some(i);
        }
        start = i + needle.len();
    }
    None
}

/// The identifier a `let` binding or struct field introduces on this
/// line, when its type or initializer names a `HashMap`/`HashSet`
/// (including the crate's `FxHashMap`).
fn hash_binding_name(line: &str) -> Option<String> {
    if !(line.contains("HashMap") || line.contains("HashSet")) {
        return None;
    }
    let t = line.trim_start();
    let t = t.strip_prefix("pub ").unwrap_or(t);
    let t = t.strip_prefix("pub(crate) ").unwrap_or(t);
    let t = match t.strip_prefix("let ") {
        Some(r) => r.strip_prefix("mut ").unwrap_or(r),
        None => t,
    };
    let name: String = t.chars().take_while(|&c| is_ident_char(c)).collect();
    if name.is_empty() {
        return None;
    }
    // only `name: Type` or `name = init` forms introduce a binding
    let after = t[name.len()..].trim_start();
    if (after.starts_with(':') && !after.starts_with("::")) || after.starts_with('=') {
        Some(name)
    } else {
        None
    }
}

/// Does this line traverse `name` — by iterator method or `for … in`?
/// Keyed access (`get`/`insert`/`contains_key`/`remove`) is allowed.
fn iterates_hash(line: &str, name: &str) -> bool {
    const METHODS: [&str; 8] = [
        ".iter()",
        ".iter_mut()",
        ".keys()",
        ".values()",
        ".values_mut()",
        ".drain(",
        ".retain(",
        ".into_iter()",
    ];
    if let Some(i) = find_word(line, name) {
        let rest = &line[i + name.len()..];
        if METHODS.iter().any(|m| rest.starts_with(m)) {
            return true;
        }
    }
    if line.contains("for ") {
        if let Some(j) = line.find(" in ") {
            let tail = line[j + 4..].trim_start().trim_start_matches('&');
            let tail = tail.strip_prefix("mut ").unwrap_or(tail);
            let word: String = tail.chars().take_while(|&c| is_ident_char(c)).collect();
            if word == name {
                return true;
            }
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(rel: &str, text: &str) -> Vec<&'static str> {
        check_file(rel, text).into_iter().map(|v| v.rule).collect()
    }

    // -- std-sync ----------------------------------------------------------

    #[test]
    fn seeded_std_sync_import_fails_the_lint() {
        let fixture = "use std::sync::Mutex;\n";
        assert_eq!(rules("rust/src/engine/mod.rs", fixture), ["std-sync"]);
    }

    #[test]
    fn the_sync_facade_itself_is_exempt() {
        let fixture = "pub use std::sync::{Arc, Mutex};\n";
        assert!(rules("rust/src/sync/mod.rs", fixture).is_empty());
    }

    #[test]
    fn prose_about_std_sync_in_comments_is_not_a_violation() {
        let fixture = "//! re-exports `std::sync` under the default build\n\
                       use crate::sync::Mutex;\n";
        assert!(rules("rust/src/engine/mod.rs", fixture).is_empty());
    }

    // -- lock-unwrap -------------------------------------------------------

    #[test]
    fn seeded_lock_unwrap_fails_the_lint() {
        let fixture = "let g = self.serve.lock().unwrap();\n";
        assert_eq!(rules("rust/src/engine/mod.rs", fixture), ["lock-unwrap"]);
        let fixture = "let g = self.serve.lock().expect(\"poisoned\");\n";
        assert_eq!(rules("rust/src/engine/mod.rs", fixture), ["lock-unwrap"]);
    }

    #[test]
    fn lock_recover_is_the_blessed_spelling() {
        let fixture = "let g = lock_recover(&self.serve);\n\
                       let h = m.lock().unwrap_or_else(PoisonError::into_inner);\n";
        assert!(rules("rust/src/engine/mod.rs", fixture).is_empty());
    }

    // -- hash-iteration ----------------------------------------------------

    #[test]
    fn seeded_hash_iteration_in_a_hot_path_fails_the_lint() {
        let fixture = "let mut acc: FxHashMap<u32, f32> = FxHashMap::default();\n\
                       for (k, v) in &acc {\n    scores[*k as usize] += v;\n}\n";
        assert_eq!(rules("rust/src/hdc/kernels.rs", fixture), ["hash-iteration"]);
    }

    #[test]
    fn hash_method_iteration_in_a_hot_path_fails_the_lint() {
        let fixture = "rows: crate::util::FxHashMap<u32, Vec<f32>>,\n\
                       let total: f32 = self.rows.values().map(|r| r[0]).sum();\n";
        assert_eq!(rules("rust/src/engine/backend.rs", fixture), ["hash-iteration"]);
    }

    #[test]
    fn keyed_lookup_in_a_hot_path_is_allowed() {
        let fixture = "rows: crate::util::FxHashMap<u32, Vec<f32>>,\n\
                       if self.rows.contains_key(&j) {\n    return self.rows.get(&j);\n}\n\
                       self.rows.entry(j).or_insert(rowq)\n";
        assert!(rules("rust/src/engine/backend.rs", fixture).is_empty());
    }

    #[test]
    fn hash_iteration_outside_hot_paths_is_allowed() {
        let fixture = "let mut acc: FxHashMap<u32, f32> = FxHashMap::default();\n\
                       for (k, v) in &acc {\n}\n";
        assert!(rules("rust/src/kg/mod.rs", fixture).is_empty());
    }

    #[test]
    fn identifier_matching_respects_word_boundaries() {
        // `borrows.iter()` must not match the binding `rows`
        let fixture = "rows: crate::util::FxHashMap<u32, Vec<f32>>,\n\
                       let n = borrows.iter().count();\n";
        assert!(rules("rust/src/engine/backend.rs", fixture).is_empty());
    }

    // -- lock-order --------------------------------------------------------

    #[test]
    fn seeded_out_of_order_acquisition_fails_the_lint() {
        let fixture = "fn broken(&self) {\n\
                           let adj = lock_recover_ranked(&self.adj, LockRank::Adj);\n\
                           let mem = lock_recover_ranked(&self.mem, LockRank::Mem);\n\
                       }\n";
        assert_eq!(rules("rust/src/engine/mod.rs", fixture), ["lock-order"]);
    }

    #[test]
    fn hierarchy_order_acquisition_passes() {
        let fixture = "fn fine(&self) {\n\
                           let mem = lock_recover_ranked(&self.mem, LockRank::Mem);\n\
                           let adj = lock_recover_ranked(&self.adj, LockRank::Adj);\n\
                       }\n";
        assert!(rules("rust/src/engine/mod.rs", fixture).is_empty());
    }

    #[test]
    fn equal_rank_reacquisition_passes() {
        // drop-and-retake of the same lock (the serve_via_cache seam)
        let fixture = "fn probe_then_insert(cache: &Mutex<ServingCache>) {\n\
                           drop(lock_recover_ranked(cache, LockRank::Cache));\n\
                           drop(lock_recover_ranked(cache, LockRank::Cache));\n\
                       }\n";
        assert!(rules("rust/src/engine/protocol.rs", fixture).is_empty());
    }

    #[test]
    fn function_boundaries_reset_the_rank_sequence() {
        let fixture = "fn high(&self) {\n\
                           let c = lock_recover_ranked(&self.cache, LockRank::Cache);\n\
                       }\n\
                       fn low(&self) {\n\
                           let s = lock_recover_ranked(&self.serve, LockRank::Serve);\n\
                       }\n";
        assert!(rules("rust/src/engine/mod.rs", fixture).is_empty());
    }

    #[test]
    fn allow_marker_waives_a_drop_and_reacquire() {
        let fixture = "fn waived(&self) {\n\
                           drop(lock_recover_ranked(&self.adj, LockRank::Adj));\n\
                           let m = lock_recover_ranked(&self.mem, LockRank::Mem); \
                       // lint: allow-lock-order\n\
                       }\n";
        assert!(rules("rust/src/engine/mod.rs", fixture).is_empty());
    }

    // -- the real tree -----------------------------------------------------

    /// The production tree must be clean: this is the same scan `make ci`
    /// runs, so a regression fails both the lint step and the test suite.
    #[test]
    fn the_checked_in_tree_is_clean() {
        let mut violations = Vec::new();
        let mut files = 0;
        for (rel, text) in collect_repo_files() {
            files += 1;
            violations.extend(check_file(&rel, &text));
        }
        assert!(files > 30, "scan found only {files} files — roots misconfigured?");
        let rendered: Vec<String> = violations.iter().map(|v| v.to_string()).collect();
        assert!(rendered.is_empty(), "lint violations in the tree:\n{}", rendered.join("\n"));
    }
}
