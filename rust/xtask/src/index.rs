//! Per-crate function index and conservative intra-crate call graph.
//!
//! Built on the token stream from [`crate::lexer`]. The index records
//! every `fn` item (including nested fns, trait defaults, and methods)
//! with its body token range, whether it lives under test configuration
//! (`#[test]` / an enclosing `#[cfg(test)]` scope), and whether it is
//! annotated `#[hdr_hot_path]`.
//!
//! The call graph is name-based and deliberately over-approximate:
//! `ident(` resolves to *every* non-test function of that name in the
//! crate, so reachability never misses a real edge at the cost of some
//! spurious ones. A stoplist of ubiquitous std/collection method names
//! keeps the spurious edges from swallowing the whole crate.

use crate::lexer::{self, Kind, Lexed, Tok};

#[derive(Debug)]
pub struct Func {
    pub name: String,
    pub file: String,
    pub is_test: bool,
    pub hot_path: bool,
    /// Token-index range of the body `[start, end)`, braces included;
    /// `start == end` for bodyless trait method declarations.
    pub body: (usize, usize),
    pub file_idx: usize,
}

pub struct Index {
    /// `(repo-relative path, lexed file)`, in input order.
    pub files: Vec<(String, Lexed)>,
    pub funcs: Vec<Func>,
}

/// Keywords that can directly precede `(` or `[` without being a call or
/// an indexing expression.
pub const KEYWORDS: [&str; 33] = [
    "if", "else", "match", "while", "for", "loop", "return", "in", "let", "mut", "fn", "pub",
    "use", "mod", "impl", "struct", "enum", "trait", "where", "as", "move", "ref", "break",
    "continue", "unsafe", "static", "const", "type", "dyn", "async", "await", "true", "false",
];

/// Ubiquitous method/constructor names that never resolve to crate
/// functions for call-graph purposes. Without this, `ident(` matching
/// would connect every `.insert(` or `.get(` to same-named crate fns and
/// the reachable set would swallow the whole crate.
pub const STOPLIST: [&str; 57] = [
    "new", "default", "len", "is_empty", "get", "get_mut", "insert", "remove", "push", "pop",
    "clone", "clear", "contains", "contains_key", "iter", "iter_mut", "into_iter", "next", "take",
    "drop", "fmt", "eq", "cmp", "hash", "from", "into", "as_ref", "as_mut", "to_string", "write",
    "read", "min", "max", "clamp", "abs", "map", "unwrap_or", "flush", "extend", "split", "score",
    "sum", "collect", "filter", "zip", "enumerate", "count", "position", "find", "copied",
    "spawn", "join", "unwrap", "expect", "peek", "parse", "with_capacity",
];

pub fn build(files: Vec<(String, String)>) -> Index {
    let mut lexed = Vec::new();
    let mut funcs = Vec::new();
    for (file_idx, (rel, text)) in files.into_iter().enumerate() {
        let lx = lexer::lex(&text);
        scan_items(&lx, &rel, file_idx, &mut funcs);
        lexed.push((rel, lx));
    }
    Index { files: lexed, funcs }
}

fn is_punct(t: &[Tok], p: usize, s: &str) -> bool {
    t.get(p).is_some_and(|x| x.kind == Kind::Punct && x.text == s)
}

fn scan_items(lx: &Lexed, rel: &str, file_idx: usize, funcs: &mut Vec<Func>) {
    let t = &lx.toks;
    let n = t.len();
    // one bool per open brace scope: true when the scope (or an ancestor)
    // was opened under a #[cfg(test)] item
    let mut scopes: Vec<bool> = Vec::new();
    let mut pending_cfg_test = false; // #[cfg(test)] seen, next item pending
    let mut pending_test_fn = false; // #[test] seen
    let mut pending_hot = false; // #[hdr_hot_path] (any path spelling) seen
    let mut item_cfg_test = false; // cfg(test) carried to an item's `{`
    let mut i = 0usize;
    while i < n {
        // attribute group: collect idents up to the matching `]`
        if is_punct(t, i, "#") && is_punct(t, i + 1, "[") {
            let mut depth = 0usize;
            let mut j = i + 1;
            let mut first: Option<&str> = None;
            let mut any_test = false;
            let mut any_hot = false;
            while j < n {
                let s = &t[j];
                if s.kind == Kind::Punct && s.text == "[" {
                    depth += 1;
                } else if s.kind == Kind::Punct && s.text == "]" {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if s.kind == Kind::Ident {
                    if first.is_none() {
                        first = Some(&s.text);
                    }
                    if s.text == "test" {
                        any_test = true;
                    }
                    if s.text == "hdr_hot_path" {
                        any_hot = true;
                    }
                }
                j += 1;
            }
            if first == Some("cfg") && any_test {
                pending_cfg_test = true;
            }
            if first == Some("test") {
                pending_test_fn = true;
            }
            if any_hot {
                pending_hot = true;
            }
            i = j + 1;
            continue;
        }
        let tok = &t[i];
        match (tok.kind, tok.text.as_str()) {
            // items whose body scope should inherit a pending cfg(test)
            (Kind::Ident, "mod") | (Kind::Ident, "impl") | (Kind::Ident, "struct")
            | (Kind::Ident, "enum") | (Kind::Ident, "trait") => {
                item_cfg_test = item_cfg_test || pending_cfg_test;
                pending_cfg_test = false;
                pending_test_fn = false;
                i += 1;
            }
            (Kind::Ident, "fn") => {
                // an fn item iff followed by a name (excludes `fn(..)`
                // pointer types and `Fn(..)` bounds)
                if t.get(i + 1).is_some_and(|x| x.kind == Kind::Ident) {
                    let name = t[i + 1].text.clone();
                    let in_test_scope = scopes.last().copied().unwrap_or(false);
                    // body: first `{` (or `;` — bodyless) at paren depth 0
                    let mut j = i + 2;
                    let mut paren = 0i32;
                    let mut body = (0usize, 0usize);
                    while j < n {
                        let s = &t[j];
                        if s.kind == Kind::Punct {
                            match s.text.as_str() {
                                "(" => paren += 1,
                                ")" => paren -= 1,
                                ";" if paren == 0 => break,
                                "{" if paren == 0 => {
                                    body = (j, find_close_brace(t, j));
                                    break;
                                }
                                _ => {}
                            }
                        }
                        j += 1;
                    }
                    funcs.push(Func {
                        name,
                        file: rel.to_string(),
                        is_test: in_test_scope || pending_test_fn || pending_cfg_test,
                        hot_path: pending_hot,
                        body,
                        file_idx,
                    });
                    pending_cfg_test = false;
                    pending_test_fn = false;
                    pending_hot = false;
                    // keep scanning inside the body so nested fns index too
                    i += 2;
                } else {
                    i += 1;
                }
            }
            (Kind::Punct, "{") => {
                scopes.push(item_cfg_test || scopes.last().copied().unwrap_or(false));
                item_cfg_test = false;
                pending_cfg_test = false;
                pending_test_fn = false;
                pending_hot = false;
                i += 1;
            }
            (Kind::Punct, "}") => {
                scopes.pop();
                i += 1;
            }
            (Kind::Punct, ";") => {
                pending_cfg_test = false;
                pending_test_fn = false;
                pending_hot = false;
                item_cfg_test = false;
                i += 1;
            }
            _ => i += 1,
        }
    }
}

fn find_close_brace(t: &[Tok], open: usize) -> usize {
    let mut depth = 0i32;
    let mut j = open;
    while j < t.len() {
        if t[j].kind == Kind::Punct {
            if t[j].text == "{" {
                depth += 1;
            } else if t[j].text == "}" {
                depth -= 1;
                if depth == 0 {
                    return j + 1;
                }
            }
        }
        j += 1;
    }
    t.len()
}

impl Index {
    /// Per-token owner map for one file: `owners[pos]` is the innermost
    /// function whose body contains token `pos`. One pass per file instead
    /// of an O(|funcs|) probe per token.
    pub fn owners(&self, file_idx: usize) -> Vec<Option<usize>> {
        let n = self.files[file_idx].1.toks.len();
        let mut own: Vec<Option<usize>> = vec![None; n];
        for (k, f) in self.funcs.iter().enumerate() {
            if f.file_idx != file_idx {
                continue;
            }
            let span = f.body.1 - f.body.0;
            let hi = f.body.1.min(n);
            for slot in own[f.body.0..hi].iter_mut() {
                let better = match *slot {
                    None => true,
                    Some(prev) => {
                        let pf = &self.funcs[prev];
                        span < pf.body.1 - pf.body.0
                    }
                };
                if better {
                    *slot = Some(k);
                }
            }
        }
        own
    }

    /// Names called from `f`'s body: any `ident(` where the ident is not a
    /// keyword, not stoplisted, and not the name in an `fn name(` item.
    pub fn callees(&self, f: &Func) -> Vec<String> {
        let toks = &self.files[f.file_idx].1.toks;
        let hi = f.body.1.min(toks.len());
        let mut out: Vec<String> = Vec::new();
        let mut p = f.body.0;
        while p + 1 < hi {
            let a = &toks[p];
            let b = &toks[p + 1];
            if a.kind == Kind::Ident
                && b.kind == Kind::Punct
                && b.text == "("
                && !KEYWORDS.contains(&a.text.as_str())
                && !STOPLIST.contains(&a.text.as_str())
                && !(p > 0 && toks[p - 1].kind == Kind::Ident && toks[p - 1].text == "fn")
                && !out.contains(&a.text)
            {
                out.push(a.text.clone());
            }
            p += 1;
        }
        out
    }

    /// BFS over the name-resolved call graph from the serving entry
    /// points. Returns `(reachable, parent)` per function index; `parent`
    /// chains render the "reachable via" note in diagnostics.
    pub fn reachable_from(&self, roots: &[&str]) -> (Vec<bool>, Vec<Option<usize>>) {
        let mut by_name: std::collections::HashMap<&str, Vec<usize>> =
            std::collections::HashMap::new();
        for (k, f) in self.funcs.iter().enumerate() {
            if !f.is_test {
                by_name.entry(f.name.as_str()).or_default().push(k);
            }
        }
        let mut reach = vec![false; self.funcs.len()];
        let mut parent: Vec<Option<usize>> = vec![None; self.funcs.len()];
        let mut queue: Vec<usize> = Vec::new();
        for (k, f) in self.funcs.iter().enumerate() {
            if !f.is_test && roots.contains(&f.name.as_str()) {
                reach[k] = true;
                queue.push(k);
            }
        }
        let mut qi = 0usize;
        while qi < queue.len() {
            let k = queue[qi];
            qi += 1;
            let names = self.callees(&self.funcs[k]);
            for name in names {
                if let Some(targets) = by_name.get(name.as_str()) {
                    for &tgt in targets {
                        if !reach[tgt] {
                            reach[tgt] = true;
                            parent[tgt] = Some(k);
                            queue.push(tgt);
                        }
                    }
                }
            }
        }
        (reach, parent)
    }

    /// Root-to-function call chain, e.g. `rank_requests → sweep_tops → f`.
    pub fn chain(&self, parent: &[Option<usize>], mut k: usize) -> String {
        let mut names = vec![self.funcs[k].name.clone()];
        while let Some(p) = parent[k] {
            names.push(self.funcs[p].name.clone());
            k = p;
        }
        names.reverse();
        names.join(" → ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idx(src: &str) -> Index {
        build(vec![("rust/src/fixture.rs".to_string(), src.to_string())])
    }

    #[test]
    fn fns_in_cfg_test_modules_are_marked_test() {
        let ix = idx(
            "fn live() {}\n\
             #[cfg(test)]\nmod tests {\n    fn helper() {}\n    #[test]\n    fn t() {}\n}\n",
        );
        let get = |n: &str| ix.funcs.iter().find(|f| f.name == n).unwrap();
        assert!(!get("live").is_test);
        assert!(get("helper").is_test);
        assert!(get("t").is_test);
    }

    #[test]
    fn hot_path_attribute_is_recorded() {
        let ix = idx("#[crate::hdr_hot_path]\nfn kernel(x: &mut [f32]) { x[0] = 1.0; }\n");
        assert!(ix.funcs[0].hot_path);
    }

    #[test]
    fn fn_pointer_types_are_not_items() {
        let ix = idx("fn takes(f: fn(u32) -> u32) -> u32 { f(1) }\n");
        assert_eq!(ix.funcs.len(), 1);
        assert_eq!(ix.funcs[0].name, "takes");
    }

    #[test]
    fn reachability_follows_call_chains_not_stoplisted_names() {
        let ix = idx(
            "fn serve() { step_one(); }\n\
             fn step_one() { v.insert(1); step_two(); }\n\
             fn step_two() {}\n\
             fn insert() {}\n\
             fn unrelated() {}\n",
        );
        let (reach, parent) = ix.reachable_from(&["serve"]);
        let r = |n: &str| {
            let k = ix.funcs.iter().position(|f| f.name == n).unwrap();
            reach[k]
        };
        assert!(r("serve") && r("step_one") && r("step_two"));
        assert!(!r("insert"), "stoplisted names must not resolve");
        assert!(!r("unrelated"));
        let k2 = ix.funcs.iter().position(|f| f.name == "step_two").unwrap();
        assert_eq!(ix.chain(&parent, k2), "serve → step_one → step_two");
    }

    #[test]
    fn owner_map_attributes_nested_fns_to_the_innermost() {
        let ix = idx("fn outer() {\n    fn inner() { x.unwrap(); }\n}\n");
        let inner = ix.funcs.iter().position(|f| f.name == "inner").unwrap();
        let owners = ix.owners(0);
        let pos = ix.funcs[inner].body.0 + 1;
        assert_eq!(owners[pos], Some(inner));
        assert_eq!(owners[ix.funcs[inner].body.0 - 1], Some(1 - inner));
    }
}
