//! Diagnostic records, rustc-style human rendering, JSON machine output,
//! and the grandfathered-findings baseline.
//!
//! The baseline (`rust/analyze-baseline.json`) is a checked-in JSON array
//! of `{code, file, function}` entries. A finding matching an entry is
//! reported as *baselined* (exit 0); an entry matching no finding is
//! *stale* and fails the run — the baseline may only shrink, never grow.

use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    pub code: String,
    pub file: String,
    pub line: usize,
    pub function: String,
    pub message: String,
    /// Secondary context, e.g. the reachability chain. Empty when absent.
    pub note: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "error[{}]: {}", self.code, self.message)?;
        write!(f, "  --> {}:{} (in `{}`)", self.file, self.line, self.function)?;
        if !self.note.is_empty() {
            write!(f, "\n  = note: {}", self.note)?;
        }
        Ok(())
    }
}

fn json_quote(s: &str) -> String {
    let mut q = String::from("\"");
    for c in s.chars() {
        match c {
            '"' => q.push_str("\\\""),
            '\\' => q.push_str("\\\\"),
            '\n' => q.push_str("\\n"),
            '\t' => q.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                q.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => q.push(c),
        }
    }
    q.push('"');
    q
}

/// Render findings as a JSON array. `baselined` marks entries suppressed
/// by the checked-in baseline (reported for the artifact, not the gate).
pub fn to_json(fresh: &[Diagnostic], baselined: &[Diagnostic]) -> String {
    let mut s = String::from("[");
    let mut first = true;
    for (d, base) in fresh
        .iter()
        .map(|d| (d, false))
        .chain(baselined.iter().map(|d| (d, true)))
    {
        if !first {
            s.push(',');
        }
        first = false;
        s.push_str("\n  {");
        s.push_str(&format!("\"code\":{},", json_quote(&d.code)));
        s.push_str(&format!("\"file\":{},", json_quote(&d.file)));
        s.push_str(&format!("\"line\":{},", d.line));
        s.push_str(&format!("\"function\":{},", json_quote(&d.function)));
        s.push_str(&format!("\"message\":{},", json_quote(&d.message)));
        s.push_str(&format!("\"note\":{},", json_quote(&d.note)));
        s.push_str(&format!("\"baselined\":{}", base));
        s.push('}');
    }
    if !first {
        s.push('\n');
    }
    s.push_str("]\n");
    s
}

#[derive(Debug, Clone, PartialEq)]
pub struct BaselineEntry {
    pub code: String,
    pub file: String,
    pub function: String,
}

/// Parse the baseline file: a JSON array of flat objects with string
/// values. Minimal by design — the analyzer writes this shape and xtask
/// stays dependency-free.
pub fn parse_baseline(text: &str) -> Result<Vec<BaselineEntry>, String> {
    let b = text.as_bytes();
    let mut i = 0usize;
    let skip_ws = |i: &mut usize| {
        while *i < b.len() && (b[*i] as char).is_whitespace() {
            *i += 1;
        }
    };
    let mut out = Vec::new();
    skip_ws(&mut i);
    if i >= b.len() || b[i] != b'[' {
        return Err("baseline: expected a JSON array".to_string());
    }
    i += 1;
    loop {
        skip_ws(&mut i);
        if i < b.len() && b[i] == b']' {
            return Ok(out);
        }
        if i < b.len() && b[i] == b',' {
            i += 1;
            continue;
        }
        if i >= b.len() || b[i] != b'{' {
            return Err(format!("baseline: expected an object at byte {i}"));
        }
        i += 1;
        let mut code = String::new();
        let mut file = String::new();
        let mut function = String::new();
        loop {
            skip_ws(&mut i);
            if i < b.len() && b[i] == b'}' {
                i += 1;
                break;
            }
            if i < b.len() && (b[i] == b',' || b[i] == b':') {
                i += 1;
                continue;
            }
            if i < b.len() && b[i] == b'"' {
                let key = parse_json_string(b, &mut i)?;
                skip_ws(&mut i);
                if i < b.len() && b[i] == b':' {
                    i += 1;
                }
                skip_ws(&mut i);
                if i < b.len() && b[i] == b'"' {
                    let val = parse_json_string(b, &mut i)?;
                    match key.as_str() {
                        "code" => code = val,
                        "file" => file = val,
                        "function" => function = val,
                        _ => {}
                    }
                } else {
                    // non-string value (a number, bool): skip the scalar
                    while i < b.len() && !matches!(b[i], b',' | b'}') {
                        i += 1;
                    }
                }
                continue;
            }
            return Err(format!("baseline: unexpected byte at {i}"));
        }
        if code.is_empty() || file.is_empty() || function.is_empty() {
            return Err("baseline: entries need code, file, and function".to_string());
        }
        out.push(BaselineEntry { code, file, function });
    }
}

fn parse_json_string(b: &[u8], i: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*i], b'"');
    *i += 1;
    let mut s = String::new();
    while *i < b.len() {
        match b[*i] {
            b'"' => {
                *i += 1;
                return Ok(s);
            }
            b'\\' => {
                *i += 1;
                if *i < b.len() {
                    match b[*i] {
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        other => s.push(other as char),
                    }
                    *i += 1;
                }
            }
            other => {
                s.push(other as char);
                *i += 1;
            }
        }
    }
    Err("baseline: unterminated string".to_string())
}

/// Split findings into `(fresh, baselined)` and report stale entries.
/// An entry covers every finding with the same `(code, file, function)`
/// (line numbers shift too easily to key on).
pub fn apply_baseline(
    diags: Vec<Diagnostic>,
    base: &[BaselineEntry],
) -> (Vec<Diagnostic>, Vec<Diagnostic>, Vec<BaselineEntry>) {
    let mut fresh = Vec::new();
    let mut grandfathered = Vec::new();
    let mut used = vec![false; base.len()];
    for d in diags {
        let hit = base
            .iter()
            .position(|e| e.code == d.code && e.file == d.file && e.function == d.function);
        match hit {
            Some(k) => {
                used[k] = true;
                grandfathered.push(d);
            }
            None => fresh.push(d),
        }
    }
    let stale = base
        .iter()
        .zip(&used)
        .filter(|&(_, &u)| !u)
        .map(|(e, _)| e.clone())
        .collect();
    (fresh, grandfathered, stale)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d(code: &str, file: &str, function: &str) -> Diagnostic {
        Diagnostic {
            code: code.to_string(),
            file: file.to_string(),
            line: 7,
            function: function.to_string(),
            message: "msg".to_string(),
            note: String::new(),
        }
    }

    #[test]
    fn human_rendering_is_rustc_style() {
        let mut diag = d("HDR-PANIC", "rust/src/engine/mod.rs", "lead");
        diag.note = "reachable from submit via lead".to_string();
        let s = diag.to_string();
        assert!(s.starts_with("error[HDR-PANIC]: msg"));
        assert!(s.contains("--> rust/src/engine/mod.rs:7 (in `lead`)"));
        assert!(s.contains("= note: reachable from submit via lead"));
    }

    #[test]
    fn json_escapes_and_round_trips_through_the_baseline_parser() {
        let mut diag = d("HDR-ALLOC", "rust/src/hdc/kernels.rs", "f");
        diag.message = "quote \" backslash \\ newline \n done".to_string();
        let js = to_json(&[diag], &[]);
        // the writer's object shape is a superset of a baseline entry
        let parsed = parse_baseline(&js).unwrap();
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0].code, "HDR-ALLOC");
        assert_eq!(parsed[0].file, "rust/src/hdc/kernels.rs");
        assert_eq!(parsed[0].function, "f");
    }

    #[test]
    fn empty_finding_list_serializes_as_an_empty_array() {
        assert_eq!(to_json(&[], &[]), "[]\n");
        assert_eq!(parse_baseline("[]\n").unwrap(), Vec::new());
    }

    #[test]
    fn baseline_suppresses_matches_and_reports_stale_entries() {
        let base = vec![
            BaselineEntry {
                code: "HDR-PANIC".to_string(),
                file: "a.rs".to_string(),
                function: "f".to_string(),
            },
            BaselineEntry {
                code: "HDR-FLOAT".to_string(),
                file: "gone.rs".to_string(),
                function: "g".to_string(),
            },
        ];
        let (fresh, grand, stale) =
            apply_baseline(vec![d("HDR-PANIC", "a.rs", "f"), d("HDR-PANIC", "b.rs", "h")], &base);
        assert_eq!(fresh.len(), 1);
        assert_eq!(fresh[0].file, "b.rs");
        assert_eq!(grand.len(), 1);
        assert_eq!(stale.len(), 1);
        assert_eq!(stale[0].file, "gone.rs");
    }
}
