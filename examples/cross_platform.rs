//! Cross-model / cross-platform comparison — Fig. 11 + the headline §5.4
//! claims as a runnable example. FPGA rows come from the cycle simulator;
//! GPU/CPU rows from the Table-6-calibrated roofline models; comparator
//! accelerators (GraphACT / HP-GNN / LookHD) from their published-spec
//! models (DESIGN.md §1). A host-CPU serving row measured live through the
//! [`hdreason::engine::KgcEngine`] anchors the modelled platforms to real
//! silicon in this process.

use hdreason::bench::figures;
use hdreason::engine::{BackendKind, EngineBuilder, QueryRequest};
use std::time::Instant;

fn main() -> hdreason::Result<()> {
    let scale = std::env::args()
        .skip_while(|a| a != "--scale")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0);
    println!("{}", figures::fig11(scale)?);
    println!("{}", figures::table6(scale)?);
    println!("{}", figures::headline(scale)?);

    // measured host reference: the engine's batched score path on this CPU
    // (tiny preset), per scoring backend
    println!("host engine serving reference (tiny preset, measured live):");
    for kind in [BackendKind::Scalar, BackendKind::Kernel] {
        let engine = EngineBuilder::new("tiny").seed(0).backend(kind).build()?;
        let kg = engine.kg();
        let reqs: Vec<QueryRequest> = (0..engine.batch_capacity())
            .map(|i| {
                let t = kg.train[i % kg.train.len()];
                QueryRequest::forward(t.src, t.rel)
            })
            .collect();
        // one warm pass, then measure a few batches
        let pairs: Vec<(usize, usize)> = reqs.iter().map(|r| (r.node, r.rel)).collect();
        std::hint::black_box(engine.score_batch(&pairs));
        let iters = 20;
        let start = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(engine.score_batch(&pairs));
        }
        let per_batch = start.elapsed().as_secs_f64() / iters as f64;
        println!(
            "  {:<8} backend: {:>8.3} ms / {}-query batch  ({:.0} queries/s)",
            engine.backend_name(),
            per_batch * 1e3,
            pairs.len(),
            pairs.len() as f64 / per_batch.max(1e-9)
        );
    }
    println!("\ncross_platform OK");
    Ok(())
}
