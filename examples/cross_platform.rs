//! Cross-model / cross-platform comparison — Fig. 11 + the headline §5.4
//! claims as a runnable example. FPGA rows come from the cycle simulator;
//! GPU/CPU rows from the Table-6-calibrated roofline models; comparator
//! accelerators (GraphACT / HP-GNN / LookHD) from their published-spec
//! models (DESIGN.md §1).

use hdreason::bench::figures;

fn main() -> hdreason::Result<()> {
    let scale = std::env::args()
        .skip_while(|a| a != "--scale")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0);
    println!("{}", figures::fig11(scale)?);
    println!("{}", figures::table6(scale)?);
    println!("{}", figures::headline(scale)?);
    println!("cross_platform OK");
    Ok(())
}
