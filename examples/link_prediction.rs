//! Link prediction + serving demo: train HDReason (PJRT artifacts when
//! present, the host-native runtime otherwise), hand the trained state to
//! a [`hdreason::engine::KgcEngine`], answer (subject, relation, ?)
//! queries through the engine's serving path, and compare HDReason against
//! the TransE / DistMult baselines on identical data through the one
//! generic `KgcModel` eval path — the Fig. 8(a) experiment at example
//! scale. Runs in every build; no artifacts required.

use hdreason::baselines::{self, train_margin_model};
use hdreason::config::RunConfig;
use hdreason::coordinator::HdrTrainer;
use hdreason::engine::{evaluate_forward, BackendKind, EngineBuilder, KgcModel, QueryRequest};
use hdreason::kg::{generator, LabelBatch};
use hdreason::runtime::{HdrRuntime, HostRuntime, Manifest, TrainerRuntime};

fn main() -> hdreason::Result<()> {
    let mut rc = RunConfig::from_presets("tiny", "u50")?;
    rc.train.epochs = 48;
    rc.train.steps_per_epoch = 16;
    rc.train.lr = 2e-2;
    rc.train.eval_every = 0;
    let kg = generator::learnable_for_preset(&rc.model, 0.8, 7);
    println!(
        "KG: {} vertices, {} relations, {} train triples",
        kg.num_vertices,
        kg.num_relations,
        kg.train.len()
    );

    let runtime: TrainerRuntime = match Manifest::load(&Manifest::default_dir())
        .and_then(|m| HdrRuntime::load(&m, &rc.model))
    {
        Ok(rt) => rt.into(),
        Err(_) => HostRuntime::with_kernel(&rc.model, 0).into(),
    };
    println!("training runtime: {}", runtime.describe());
    let mut trainer = HdrTrainer::new(rc, runtime, &kg)?;
    trainer.fit()?;

    // ---- serve the trained model through the engine ---------------------
    let engine = EngineBuilder::new("tiny")
        .graph(kg.clone())
        .state(trainer.state.clone())
        .backend(BackendKind::Kernel)
        .build()?;
    println!(
        "\nengine: backend {}, serving batch {} — link prediction on test triples:",
        engine.backend_name(),
        engine.batch_capacity()
    );
    for t in kg.test.iter().take(4) {
        let r = engine.submit(QueryRequest::forward(t.src, t.rel));
        let top3: Vec<usize> = r.top.iter().take(3).map(|&(v, _)| v).collect();
        let rank = r
            .top
            .iter()
            .position(|&(v, _)| v == t.dst)
            .map(|p| (p + 1).to_string())
            .unwrap_or_else(|| format!(">{}", r.top.len()));
        println!("  ({}, r{}, ?) -> top3 {:?} (gold {} at rank {})", t.src, t.rel, top3, t.dst, rank);
    }

    // ---- accuracy comparison: one generic KgcModel eval path ------------
    println!("\naccuracy comparison (filtered test metrics):");
    println!("{}", trainer.evaluate(&kg.test)?.row("HDReason (trainer)"));
    println!("{}", engine.evaluate(&kg.test)?.row("HDReason (engine)"));

    let labels = LabelBatch::full(&kg);
    let queries: Vec<_> = kg.test.iter().map(|t| (t.src, t.rel, t.dst)).collect();
    let mut transe = baselines::TransE::new(kg.num_vertices, kg.num_relations, 32, 0);
    train_margin_model(&mut transe, &kg, 30, 0.05, 1.0, 0);
    let mut dm = baselines::DistMult::new(kg.num_vertices, kg.num_relations, 32, 0);
    train_margin_model(&mut dm, &kg, 30, 0.05, 1.0, 0);
    let rows: [(&dyn KgcModel, &str); 2] = [(&transe, "TransE"), (&dm, "DistMult")];
    for (model, label) in rows {
        println!("{}", evaluate_forward(model, &queries, &labels, 64)?.row(label));
    }
    println!("\nlink_prediction OK");
    Ok(())
}
