//! Link prediction + interpretability demo: train on a Table-3-matched
//! synthetic FB15K-237 (scaled into the fb15k_mini preset box), answer
//! (subject, relation, ?) queries, and compare HDReason against the
//! TransE / DistMult / R-GCN baselines on identical data — the Fig. 8(a)
//! experiment at example scale.

use hdreason::baselines::{self, train_margin_model};
use hdreason::config::RunConfig;
use hdreason::coordinator::HdrTrainer;
use hdreason::kg::{generator, LabelBatch};
use hdreason::model::{evaluate_ranking, sigmoid};
use hdreason::runtime::{HdrRuntime, Manifest};

fn main() -> hdreason::Result<()> {
    let mut rc = RunConfig::from_presets("tiny", "u50")?;
    rc.train.epochs = 48;
    rc.train.steps_per_epoch = 16;
    rc.train.lr = 2e-2;
    rc.train.eval_every = 0;
    let kg = generator::learnable_for_preset(&rc.model, 0.8, 7);
    println!("KG: {} vertices, {} relations, {} train triples",
             kg.num_vertices, kg.num_relations, kg.train.len());

    let manifest = Manifest::load(&Manifest::default_dir())?;
    let runtime = HdrRuntime::load(&manifest, &rc.model)?;
    let batch = rc.model.batch;
    let mut trainer = HdrTrainer::new(rc, runtime, &kg)?;
    trainer.fit()?;

    // ---- answer a handful of test queries ------------------------------
    println!("\nlink prediction on test triples (top-3 candidates):");
    let v = trainer.state.cfg.num_vertices;
    let show = kg.test.iter().take(4).collect::<Vec<_>>();
    let mut qs = vec![0i32; batch];
    let mut qr = vec![0i32; batch];
    for (i, t) in show.iter().enumerate() {
        qs[i] = t.src as i32;
        qr[i] = t.rel as i32;
    }
    let logits = trainer.runtime().forward(&trainer.state, trainer.edges(), &qs, &qr, 6.0)?;
    for (i, t) in show.iter().enumerate() {
        let row = &logits[i * v..(i + 1) * v];
        let mut idx: Vec<usize> = (0..v).collect();
        idx.sort_by(|&a, &b| row[b].total_cmp(&row[a]));
        let rank = idx.iter().position(|&x| x == t.dst).unwrap() + 1;
        println!(
            "  ({}, r{}, ?) -> top3 {:?} (gold {} at rank {}, p={:.3})",
            t.src, t.rel, &idx[..3], t.dst, rank, sigmoid(row[t.dst])
        );
    }

    // ---- baselines on the same graph ------------------------------------
    println!("\naccuracy comparison (filtered test metrics):");
    println!("{}", trainer.evaluate(&kg.test)?.row("HDReason (PJRT)"));
    let labels = LabelBatch::full(&kg);
    let queries: Vec<_> = kg.test.iter().map(|t| (t.src, t.rel, t.dst)).collect();
    let mut transe = baselines::TransE::new(kg.num_vertices, kg.num_relations, 32, 0);
    train_margin_model(&mut transe, &kg, 30, 0.05, 1.0, 0);
    println!("{}", evaluate_ranking(&queries, &labels, |s, r| {
        baselines::MarginModel::score_all_objects(&transe, s, r)
    }).row("TransE"));
    let mut dm = baselines::DistMult::new(kg.num_vertices, kg.num_relations, 32, 0);
    train_margin_model(&mut dm, &kg, 30, 0.05, 1.0, 0);
    println!("{}", evaluate_ranking(&queries, &labels, |s, r| {
        baselines::MarginModel::score_all_objects(&dm, s, r)
    }).row("DistMult"));
    println!("\nlink_prediction OK");
    Ok(())
}
