//! Accelerator design-space exploration — the Fig. 10 experiment as a
//! runnable example: sweep UltraRAM budget × replacement policy over the
//! four paper datasets (scaled by --scale, default 0.25), reporting
//! memorization latency and FPGA↔HBM traffic; then the Fig. 8(c)
//! optimization ablation. Closes with a live serving sweep through the
//! [`hdreason::engine::KgcEngine`] micro-batcher — the software knob
//! (batch capacity) that mirrors the hardware's batch amortization.

use hdreason::bench::figures;
use hdreason::engine::{BackendKind, EngineBuilder, QueryRequest};
use std::time::{Duration, Instant};

fn main() -> hdreason::Result<()> {
    let scale = std::env::args()
        .skip_while(|a| a != "--scale")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.25);
    println!("{}", figures::fig10(scale)?);
    println!("{}", figures::fig8c(scale)?);

    // serving-batch sweep: same engine, same queries, different coalescing
    println!("engine serving sweep (tiny preset, kernel backend, measured live):");
    for capacity in [1usize, 8, 32] {
        let engine = EngineBuilder::new("tiny")
            .seed(0)
            .backend(BackendKind::Kernel)
            .batch_capacity(capacity)
            .deadline(Duration::from_micros(200))
            .build()?;
        let kg = engine.kg();
        let reqs: Vec<QueryRequest> = (0..256)
            .map(|i| {
                let t = kg.train[i % kg.train.len()];
                QueryRequest::forward(t.src, t.rel)
            })
            .collect();
        // one client per serving slot so full batches actually form
        let start = Instant::now();
        engine.serve_all(&reqs, capacity);
        let elapsed = start.elapsed().as_secs_f64().max(1e-9);
        println!(
            "  batch {:>3}: {:>7.1} ms for {} queries  ({:.0} queries/s)",
            capacity,
            elapsed * 1e3,
            reqs.len(),
            reqs.len() as f64 / elapsed
        );
    }
    println!("\naccelerator_sweep OK");
    Ok(())
}
