//! Accelerator design-space exploration — the Fig. 10 experiment as a
//! runnable example: sweep UltraRAM budget × replacement policy over the
//! four paper datasets (scaled by --scale, default 0.25), reporting
//! memorization latency and FPGA↔HBM traffic; then the Fig. 8(c)
//! optimization ablation.

use hdreason::bench::figures;

fn main() -> hdreason::Result<()> {
    let scale = std::env::args()
        .skip_while(|a| a != "--scale")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.25);
    println!("{}", figures::fig10(scale)?);
    println!("{}", figures::fig8c(scale)?);
    println!("accelerator_sweep OK");
    Ok(())
}
