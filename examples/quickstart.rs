//! Quickstart: the engine is the front door.
//!
//! Builds a [`hdreason::engine::KgcEngine`] over a small learnable
//! synthetic KG — no AOT artifacts required — and walks the serving
//! surface: single-query ranking, the micro-batched `submit` path,
//! filtered double-direction evaluation, and the §3.3 interpretability
//! query. It then trains end-to-end — through the PJRT artifacts when
//! present (`make artifacts` + `--features pjrt`), through the host-native
//! `runtime::HostRuntime` otherwise — and rebuilds the engine from the
//! trained state to show the accuracy moving.
//!
//!     cargo run --release --example quickstart

use hdreason::config::accel_preset;
use hdreason::coordinator::HdrTrainer;
use hdreason::engine::{BackendKind, EngineBuilder, QuantBackend, QueryRequest, ShardedBackend};
use hdreason::hdc;
use hdreason::runtime::{HdrRuntime, HostRuntime, Manifest, TrainerRuntime};
use hdreason::sim::{simulate_batch, SimOptions, Workload};
use std::time::{Duration, Instant};

fn main() -> hdreason::Result<()> {
    // ---- the engine: preset + dataset + backend, one builder ------------
    let engine = EngineBuilder::new("tiny")
        .dataset("learnable")
        .seed(42)
        .backend(BackendKind::Kernel)
        .deadline(Duration::from_micros(500))
        .build()?;
    let kg = engine.kg().clone();
    println!(
        "KG '{}': {} vertices, {} relations, {} train / {} valid / {} test triples",
        kg.name,
        kg.num_vertices,
        kg.num_relations,
        kg.train.len(),
        kg.valid.len(),
        kg.test.len()
    );
    println!(
        "engine: backend {}, serving batch {}, {} candidates per ranking",
        engine.backend_name(),
        engine.batch_capacity(),
        engine.num_candidates()
    );

    // ---- serve queries ---------------------------------------------------
    let t = kg.test[0];
    let ranking = engine.rank(QueryRequest::forward(t.src, t.rel));
    let top3: Vec<usize> = ranking.top.iter().take(3).map(|&(v, _)| v).collect();
    println!("\nquery ({}, r{}, ?) -> top3 {:?} (gold {})", t.src, t.rel, top3, t.dst);

    // micro-batched serving: concurrent submitters coalesce into full
    // batches; compare throughput against one-at-a-time ranking
    let stream: Vec<QueryRequest> =
        (0..256).map(|i| {
            let t = kg.test[i % kg.test.len()];
            QueryRequest::forward(t.src, t.rel)
        })
        .collect();
    let start = Instant::now();
    // one client per serving slot so full batches actually form
    engine.serve_all(&stream, engine.batch_capacity());
    let batched_s = start.elapsed().as_secs_f64();
    println!(
        "served {} queries through submit() in {:.1} ms ({:.0} q/s)",
        stream.len(),
        batched_s * 1e3,
        stream.len() as f64 / batched_s.max(1e-9)
    );

    // ---- async serving: one client, the whole stream in flight -----------
    // submit_async returns a handle immediately; poll() or wait() collects.
    // Same rankings as submit(), no thread-per-query.
    let start = Instant::now();
    let handles: Vec<_> = stream.iter().map(|&q| engine.submit_async(q)).collect();
    let served = handles.len();
    for h in handles {
        let _ = h.wait();
    }
    let async_s = start.elapsed().as_secs_f64();
    println!(
        "pipelined {} queries through submit_async() in {:.1} ms ({:.0} q/s, one client)",
        served,
        async_s * 1e3,
        served as f64 / async_s.max(1e-9)
    );

    // wait_any: collect in-flight handles as they complete, regardless of
    // submission order — the bulk wait for clients with many handles
    let mut inflight: Vec<_> =
        stream.iter().take(16).map(|&q| engine.submit_async(q)).collect();
    let mut collected = 0usize;
    while !inflight.is_empty() {
        let (i, ranking) = engine.wait_any(&mut inflight);
        let done = inflight.swap_remove(i);
        assert_eq!(ranking.request, done.request());
        collected += 1;
    }
    println!("wait_any() collected {collected} completions out of submission order");

    // ---- alternative score backends (CLI: --backend sharded:N|quant:N) ---
    // sharded: fan the (|V|, D) memory-matrix scan across N workers;
    // scores are byte-identical to the kernel backend
    let sharded = EngineBuilder::new("tiny")
        .dataset("learnable")
        .seed(42)
        .custom_backend(Box::new(ShardedBackend::with_shards(4)))
        .build()?;
    // quant: score on the fix-8 grid through the fused quantize-and-score
    // kernel — Fig. 9(b)'s robustness experiment at kernel speed
    let quant = EngineBuilder::new("tiny")
        .dataset("learnable")
        .seed(42)
        .custom_backend(Box::new(QuantBackend::new(8, 0)))
        .build()?;
    // composed: the shard fan-out over the quantized leaf — what the CLI
    // spells `--backend sharded:4+quant:8`; byte-identical to plain quant
    // because the fix-N grid scales are per-row (slice-local)
    let composed = EngineBuilder::new("tiny")
        .dataset("learnable")
        .seed(42)
        .backend(BackendKind::parse("sharded:4+quant:8")?)
        .build()?;
    let req = QueryRequest::forward(t.src, t.rel);
    println!(
        "backends on ({}, r{}, ?): kernel top1 {:?}, sharded top1 {:?}, fix-8 top1 {:?}",
        t.src,
        t.rel,
        engine.rank(req).top[0],
        sharded.rank(req).top[0],
        quant.rank(req).top[0]
    );
    assert_eq!(composed.rank(req), quant.rank(req), "sharding cannot change the quant grid");
    println!("composed backend '{}' == quant:8, byte-identical", composed.backend_desc());

    // ---- filtered evaluation (untrained baseline) ------------------------
    let before = engine.evaluate(&kg.test)?;
    println!("\n{}", before.row("engine untrained (test)"));
    let both = engine.evaluate_both(&kg.test)?;
    println!("{}", both.row("engine untrained (2-dir)"));

    // ---- training, then serve the trained state --------------------------
    // PJRT artifacts when present; otherwise the host-native runtime — the
    // training section runs in every build
    match training(&kg) {
        Ok(after) => {
            println!("{}", after.row("engine trained   (test)"));
            assert!(after.mrr > before.mrr, "training must beat the untrained engine");
        }
        Err(e) => println!("\n(skipping training section: {e})"),
    }

    // ---- interpretability (§3.3): reconstruct a vertex's neighbors -------
    let state = engine.state();
    let hv = state.encode_vertices_host();
    let hr = state.encode_relations_host();
    let csr = kg.train_csr();
    let mem = hdc::memorize(&csr, &hv, &hr, state.cfg.dim_hd);
    let probe = (0..kg.num_vertices).max_by_key(|&v| csr.degree(v)).unwrap();
    let (_, rel0) = csr.neighbors(probe)[0];
    let top = hdc::reconstruct_neighbors(&mem, &hv, &hr, probe, rel0 as usize, 5);
    println!("\nneighbor reconstruction for hub vertex {probe} via relation {rel0}:");
    for (v, sim) in &top {
        let marker = if csr.neighbors(probe).iter().any(|&(s, r)| s == *v as u32 && r == rel0) {
            " <- true neighbor"
        } else {
            ""
        };
        println!("  vertex {v:>5}  cos {sim:.3}{marker}");
    }

    // ---- accelerator view: what the U50 would do with this workload ------
    let w = Workload::from_kg(&kg, state.cfg.batch, state.cfg.dim_in, state.cfg.dim_hd);
    let r = simulate_batch(&accel_preset("u50")?, &w, SimOptions::default());
    println!("\nU50 accelerator simulation of this workload:");
    println!("  {}", r.table6_row());
    println!("  {}", r.breakdown_row());
    println!("\nquickstart OK");
    Ok(())
}

/// Train end-to-end — through the PJRT artifacts when they are compiled
/// and present, through the host-native runtime otherwise — then
/// re-evaluate through a fresh engine built from the trained state.
fn training(kg: &hdreason::kg::KnowledgeGraph) -> hdreason::Result<hdreason::model::RankMetrics> {
    let mut rc = hdreason::config::RunConfig::from_presets("tiny", "u50")?;
    rc.train.epochs = 48;
    rc.train.steps_per_epoch = 16; // 768 train steps end-to-end
    rc.train.lr = 2e-2;
    rc.train.eval_every = 10;
    rc.validate()?;
    let runtime: TrainerRuntime = match Manifest::load(&Manifest::default_dir())
        .and_then(|m| HdrRuntime::load(&m, &rc.model))
    {
        Ok(rt) => rt.into(),
        Err(e) => {
            println!("\n(PJRT unavailable: {e}; training on the host-native runtime)");
            HostRuntime::with_kernel(&rc.model, 0).into()
        }
    };
    println!("training runtime: {}", runtime.describe());
    let mut trainer = HdrTrainer::new(rc, runtime, kg)?;
    trainer.fit()?;
    print!("{}", trainer.log.render());
    // the engine serves whatever state you hand it — here, the trained one
    let trained = EngineBuilder::new("tiny")
        .graph(kg.clone())
        .state(trainer.state.clone())
        .backend(BackendKind::Kernel)
        .build()?;
    trained.evaluate(&kg.test)
}
