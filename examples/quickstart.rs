//! Quickstart: the end-to-end driver (DESIGN.md §5).
//!
//! Trains HDReason on a small learnable synthetic KG for a few hundred
//! steps *through the AOT-compiled PJRT artifacts* (python never runs),
//! logs the loss curve, evaluates filtered MRR/Hits, demonstrates the
//! interpretability query of §3.3, and runs the FPGA cycle simulator on
//! the same workload to report what the accelerator would do.
//!
//!     make artifacts && cargo run --release --example quickstart

use hdreason::config::{accel_preset, RunConfig};
use hdreason::coordinator::HdrTrainer;
use hdreason::hdc;
use hdreason::kg::generator;
use hdreason::runtime::{HdrRuntime, Manifest};
use hdreason::sim::{simulate_batch, SimOptions, Workload};

fn main() -> hdreason::Result<()> {
    // ---- configuration: `tiny` preset (CPU-PJRT-friendly; use --model
    // small via the CLI for the 2048-vertex variant) -----------------
    let mut rc = RunConfig::from_presets("tiny", "u50")?;
    rc.train.epochs = 48;
    rc.train.steps_per_epoch = 16; // 768 train steps end-to-end
    rc.train.lr = 2e-2;
    rc.train.eval_every = 10;
    rc.validate()?;

    // ---- data: learnable synthetic KG sized for the preset -------------
    let kg = generator::learnable_for_preset(&rc.model, 0.8, rc.train.seed);
    println!(
        "KG '{}': {} vertices, {} relations, {} train / {} valid / {} test triples",
        kg.name, kg.num_vertices, kg.num_relations,
        kg.train.len(), kg.valid.len(), kg.test.len()
    );

    // ---- runtime: load the AOT artifacts (HLO text → PJRT) -------------
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let runtime = HdrRuntime::load(&manifest, &rc.model)?;
    println!("PJRT platform: {} (jax {} artifacts)", runtime.platform(), manifest.jax_version);

    // ---- train ----------------------------------------------------------
    let mut trainer = HdrTrainer::new(rc, runtime, &kg)?;
    let before = trainer.evaluate(&kg.test)?;
    trainer.fit()?;
    println!("\nloss curve:");
    print!("{}", trainer.log.render());
    let after = trainer.evaluate(&kg.test)?;
    println!("{}", before.row("untrained (test)"));
    println!("{}", after.row("trained   (test)"));
    assert!(after.mrr > before.mrr, "training must beat the untrained model");

    // ---- interpretability (§3.3): reconstruct a vertex's neighbors -----
    let hv = trainer.state.encode_vertices_host();
    let hr = trainer.state.encode_relations_host();
    let csr = kg.train_csr();
    let mem = hdc::memorize(&csr, &hv, &hr, trainer.state.cfg.dim_hd);
    let probe = (0..kg.num_vertices).max_by_key(|&v| csr.degree(v)).unwrap();
    let (src0, rel0) = csr.neighbors(probe)[0];
    let top = hdc::reconstruct_neighbors(&mem, &hv, &hr, probe, rel0 as usize, 5);
    println!("\nneighbor reconstruction for hub vertex {probe} via relation {rel0}:");
    for (v, sim) in &top {
        let marker = if csr.neighbors(probe).iter().any(|&(s, r)| s == *v as u32 && r == rel0) {
            " <- true neighbor"
        } else {
            ""
        };
        println!("  vertex {v:>5}  cos {sim:.3}{marker}");
    }
    let _ = src0;

    // ---- accelerator view: what the U50 would do with this workload ----
    let w = Workload::from_kg(&kg, trainer.state.cfg.batch, trainer.state.cfg.dim_in,
                              trainer.state.cfg.dim_hd);
    let r = simulate_batch(&accel_preset("u50")?, &w, SimOptions::default());
    println!("\nU50 accelerator simulation of this workload:");
    println!("  {}", r.table6_row());
    println!("  {}", r.breakdown_row());
    println!("\nquickstart OK");
    Ok(())
}
