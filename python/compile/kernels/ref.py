"""Pure-jnp oracle implementations of every Pallas kernel and the dense
matrix-form equations of the paper. pytest checks the kernels against these;
nothing here is ever lowered into an artifact.

Paper equation index:
  Eq. 5/6  — encode:      H = tanh(e @ H_B)
  Eq. 7    — bind:        H_j^v ∘ H_r^r (Hadamard)
  Eq. 1/7  — memorize:    M_i = Σ_{(j,r)∈N(i)} H_j ∘ H_r     (edge-list form)
  Eq. 8    — memorize:    M = Σ_r (A_r H^v) ∘ E^r            (dense oracle)
  Eq. 10   — score:       P = σ(bias - ||M_q + H_r - M^v||_1)
"""

import jax
import jax.numpy as jnp


def encode(e: jax.Array, hb: jax.Array) -> jax.Array:
    """Eq. 5/6: map original-space embeddings into hyperspace."""
    return jnp.tanh(jnp.matmul(e, hb, preferred_element_type=jnp.float32))


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def bind(a: jax.Array, b: jax.Array) -> jax.Array:
    """Eq. 7 binding: elementwise Hadamard product."""
    return a * b


def pairwise_l1(q: jax.Array, m: jax.Array) -> jax.Array:
    """L1 distance between every query object HDV and every memory HDV.

    q: (B, D) object hypervectors (M_q^v + H_k^r, already added)
    m: (V, D) vertex memory hypervectors
    returns (B, V) distances.
    """
    return jnp.sum(jnp.abs(q[:, None, :] - m[None, :, :]), axis=-1)


def memorize_edges(hv, hr, src, rel, dst, mask, num_vertices: int):
    """Eq. 1/7 in scatter/segment-sum (edge-list) form — the formulation the
    paper's accelerator actually implements (§4.2.1: "scatter and reduce
    operations instead of SpMM")."""
    bound = bind(hv[src], hr[rel]) * mask[:, None]
    return jax.ops.segment_sum(bound, dst, num_segments=num_vertices)


def memorize_dense(hv, hr, adj):
    """Eq. 8 dense oracle: M = Σ_r (A_r @ H^v) ∘ E^r.

    adj: (R, V, V) 3-D relation adjacency (A_r[i, j] = 1 iff (v_j, r, v_i)).
    Only usable for tiny graphs; exists to prove the edge-list form equals
    the paper's matrix form.
    """
    R = adj.shape[0]

    def body(r, acc):
        er = jnp.broadcast_to(hr[r][None, :], hv.shape)  # Eq. 9
        return acc + matmul(adj[r], hv) * er

    return jax.lax.fori_loop(0, R, body, jnp.zeros_like(hv))


def transe_logits(mv, hr, q_subj, q_rel, bias):
    """Eq. 10 (pre-sigmoid): logits[b, v] = bias - ||M_q[b] + H_r[b] - M_v||_1."""
    q = mv[q_subj] + hr[q_rel]
    return bias - pairwise_l1(q, mv)


def forward(ev, er, hb, src, rel, dst, mask, q_subj, q_rel, bias):
    """Full HDReason forward pass, pure-jnp: Eqs. 5-10."""
    hv = encode(ev, hb)
    hr = encode(er, hb)
    mv = memorize_edges(hv, hr, src, rel, dst, mask, ev.shape[0])
    return transe_logits(mv, hr, q_subj, q_rel, bias)


def bce_loss(logits, labels, smoothing: float = 0.0):
    """Numerically stable binary cross-entropy with logits + label smoothing
    (1-vs-all KGC training, as in ConvE/CompGCN and the paper's Eq. 11)."""
    if smoothing > 0.0:
        labels = labels * (1.0 - smoothing) + smoothing / labels.shape[-1]
    per = jnp.maximum(logits, 0.0) - logits * labels + jnp.log1p(
        jnp.exp(-jnp.abs(logits))
    )
    return jnp.mean(per)
