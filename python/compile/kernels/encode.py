"""L1 Pallas kernel: hyperdimensional encoding (paper Eqs. 5/6).

    H = tanh(e @ H_B)

Hardware adaptation (DESIGN.md §2): the paper implements this on an FPGA
systolic array (Fig. 5) with one PE column per hyperspace lane. On TPU the
same computation is an MXU matmul tile: we grid over (vertex tiles ×
hyperspace tiles), keep the full contraction dimension d (d ≤ 128 in every
paper configuration, Table 4) resident in VMEM, and fuse the tanh kernel
function into the tile epilogue — the FPGA's "kernel function" stage.

The backward pass is a custom VJP mirroring the paper's forward/backward
co-optimization (§4.2): dH/de = (g · (1 - H²)) @ H_Bᵀ reuses the same tiled
matmul kernel, and the tanh residual is the forward output itself (no
recompute), exactly like the accelerator stashing gradients computed on the
forward path in HBM.

Pallas is lowered with interpret=True: CPU PJRT cannot execute Mosaic
custom-calls, so interpret mode emits plain HLO that both pytest and the
rust runtime execute. Real-TPU efficiency is estimated in DESIGN.md §6.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fit_block(dim: int, want: int) -> int:
    """Largest divisor of ``dim`` that is ≤ ``want`` (shape-safe tiling for
    ragged dimensions like |R| = 240)."""
    want = min(want, dim)
    while dim % want != 0:
        want -= 1
    return want


def _matmul_kernel(a_ref, b_ref, o_ref, *, activation: str):
    acc = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    if activation == "tanh":
        acc = jnp.tanh(acc)
    o_ref[...] = acc.astype(o_ref.dtype)


def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    activation: str = "none",
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Tiled (M,K)@(K,N) matmul with optional fused tanh epilogue.

    The contraction dimension K stays whole inside each tile (K = d or V in
    all call sites; VMEM budget documented in DESIGN.md §6).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    block_m = _fit_block(m, block_m)
    block_n = _fit_block(n, block_n)
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def encode(e: jax.Array, hb: jax.Array, block_v: int = 128, block_do: int = 128):
    """Eq. 5/6: H = tanh(e @ H_B), Pallas-tiled.

    e:  (V, d) original-space embeddings (trainable)
    hb: (d, D) base hypervector matrix (fixed Gaussian, Table 2)
    """
    return matmul(e, hb, activation="tanh", block_m=block_v, block_n=block_do)


def _encode_fwd(e, hb, block_v, block_do):
    h = encode(e, hb, block_v, block_do)
    return h, (e, hb, h)


def _encode_bwd(block_v, block_do, res, g):
    e, hb, h = res
    # d tanh(z)/dz = 1 - tanh(z)^2; h IS tanh(z) — residual reuse, the
    # paper's forward-path gradient trick.
    gz = g * (1.0 - h * h)
    de = matmul(gz, hb.T, block_m=block_v, block_n=min(block_do, hb.shape[0]))
    # H_B is frozen in HDC training (§3.2), but return its true gradient so
    # the kernel is a drop-in differentiable primitive for the oracle tests.
    dhb = matmul(e.T, gz, block_m=min(block_v, e.shape[1]), block_n=block_do)
    return de.astype(e.dtype), dhb.astype(hb.dtype)


encode.defvjp(_encode_fwd, _encode_bwd)
