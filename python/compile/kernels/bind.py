"""L1 Pallas kernel: HDC binding (paper Eq. 7).

    bound[e] = H^v[src[e]] ∘ H^r[rel[e]]     (Hadamard product per edge)

On the paper's accelerator this is the Memorization Computing IP's CU array
(Fig. 5(c)): N_c vertices in flight, binding parallelised across computing
units. On TPU the natural shape is an edge-tiled elementwise kernel over the
already-gathered (E, D) operand matrices: the gathers (the Dispatcher IP's
job on the FPGA) stay in XLA where they lower to efficient dynamic-gathers,
and the bandwidth-bound multiply runs tile-by-tile in VMEM.

Backward (custom VJP): d/da = g ∘ b and d/db = g ∘ a — the same kernel,
re-invoked. This is the §4.2 observation that the memorization gradient
(Eq. 13) is computable on the forward path: binding is its own adjoint up to
operand swap.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bind_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] * b_ref[...]


def _bind_impl(a: jax.Array, b: jax.Array, block_e: int, interpret: bool = True):
    e, d = a.shape
    assert a.shape == b.shape, (a.shape, b.shape)
    block_e = min(block_e, e)
    assert e % block_e == 0, (a.shape, block_e)
    return pl.pallas_call(
        _bind_kernel,
        grid=(e // block_e,),
        in_specs=[
            pl.BlockSpec((block_e, d), lambda i: (i, 0)),
            pl.BlockSpec((block_e, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_e, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((e, d), jnp.float32),
        interpret=interpret,
    )(a, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def bind(a: jax.Array, b: jax.Array, block_e: int = 256):
    """Eq. 7: elementwise Hadamard bind of two (E, D) hypervector matrices."""
    return _bind_impl(a, b, block_e)


def _bind_fwd(a, b, block_e):
    return _bind_impl(a, b, block_e), (a, b)


def _bind_bwd(block_e, res, g):
    a, b = res
    return (
        _bind_impl(g, b, block_e).astype(a.dtype),
        _bind_impl(g, a, block_e).astype(b.dtype),
    )


bind.defvjp(_bind_fwd, _bind_bwd)
