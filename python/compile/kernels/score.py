"""L1 Pallas kernel: TransE score distance (paper Eq. 10, Fig. 6).

    dist[b, v] = || q[b] - M^v[v] ||_1       q = M_q^v + H_k^r

This is the dominant compute of HDReason inference/training: |B|·|V|·D
absolute differences per batch. The paper builds |B| Score Engine units,
each with D Norm Units feeding a Tree Adder (Fig. 6(b-d)). The TPU mapping:
a (batch-tile × vertex-tile) grid; each tile materialises the (bb, bv, D)
difference cube in VMEM, reduces over D in-register (the Tree Adder), and
writes a (bb, bv) distance tile.

Forward/backward co-optimization (§4.3): the paper's Norm Units extract
|x| AND sign(x) in one pass, stashing the sign — the L1 gradient — in HBM
for the backward phase. Our custom VJP is the same trick: backward re-reads
the (q, m) residual and two accumulation kernels produce

    dq[b] =  Σ_v g[b,v] · sign(q[b] - m[v])
    dm[v] = -Σ_b g[b,v] · sign(q[b] - m[v])

by revisiting output blocks across the inner grid dimension (`pl.when`
zero-init on the first visit), i.e. the Tree Adder running in reverse.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist_kernel(q_ref, m_ref, o_ref):
    diff = q_ref[...][:, None, :] - m_ref[...][None, :, :]  # (bb, bv, D)
    o_ref[...] = jnp.sum(jnp.abs(diff), axis=-1)


def _dq_kernel(q_ref, m_ref, g_ref, dq_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dq_ref[...] = jnp.zeros_like(dq_ref)

    s = jnp.sign(q_ref[...][:, None, :] - m_ref[...][None, :, :])  # (bb,bv,D)
    dq_ref[...] += jnp.sum(g_ref[...][:, :, None] * s, axis=1)


def _dm_kernel(q_ref, m_ref, g_ref, dm_ref):
    # grid is (vertex tiles, batch tiles): batch is the inner, accumulated dim
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        dm_ref[...] = jnp.zeros_like(dm_ref)

    s = jnp.sign(q_ref[...][:, None, :] - m_ref[...][None, :, :])  # (bb,bv,D)
    dm_ref[...] += -jnp.sum(g_ref[...][:, :, None] * s, axis=0)


def _dist_impl(q, m, block_b, block_v, interpret: bool = True):
    b, d = q.shape
    v, d2 = m.shape
    assert d == d2, (q.shape, m.shape)
    block_b, block_v = min(block_b, b), min(block_v, v)
    assert b % block_b == 0 and v % block_v == 0, (q.shape, m.shape, block_b, block_v)
    return pl.pallas_call(
        _dist_kernel,
        grid=(b // block_b, v // block_v),
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_v, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_v), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, v), jnp.float32),
        interpret=interpret,
    )(q, m)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def pairwise_l1(q: jax.Array, m: jax.Array, block_b: int = 16, block_v: int = 128):
    """(B, D) × (V, D) → (B, V) pairwise L1 distances, Pallas-tiled."""
    return _dist_impl(q, m, block_b, block_v)


def _l1_fwd(q, m, block_b, block_v):
    return _dist_impl(q, m, block_b, block_v), (q, m)


def _l1_bwd(block_b, block_v, res, g):
    q, m = res
    b, d = q.shape
    v, _ = m.shape
    bb, bv = min(block_b, b), min(block_v, v)
    interpret = True

    dq = pl.pallas_call(
        _dq_kernel,
        # output block q-tile i is revisited across inner dim j → accumulate
        grid=(b // bb, v // bv),
        in_specs=[
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bv, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bb, bv), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(q, m, g)

    dm = pl.pallas_call(
        _dm_kernel,
        # output block m-tile j is revisited across inner dim i → accumulate
        grid=(v // bv, b // bb),
        in_specs=[
            pl.BlockSpec((bb, d), lambda j, i: (i, 0)),
            pl.BlockSpec((bv, d), lambda j, i: (j, 0)),
            pl.BlockSpec((bb, bv), lambda j, i: (i, j)),
        ],
        out_specs=pl.BlockSpec((bv, d), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((v, d), jnp.float32),
        interpret=interpret,
    )(q, m, g)

    return dq.astype(q.dtype), dm.astype(m.dtype)


pairwise_l1.defvjp(_l1_fwd, _l1_bwd)
