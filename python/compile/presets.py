"""Static-shape configuration presets shared by the AOT exporter and tests.

XLA artifacts have fixed shapes; each preset pins every dimension of the
HDReason model (Table 2 of the paper):

  V  — number of KG vertices (|V|)
  R  — number of relations (|R|)
  E  — padded edge count (triples are padded to E with mask=0)
  d  — original embedding dimension
  D  — hyperspace dimension
  B  — training/query batch size

The rust side reads ``artifacts/manifest.json`` (written by aot.py) to know
which artifact matches which preset. Block sizes for the Pallas kernels are
chosen so every dimension divides evenly (asserted in ``validate``).
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class Preset:
    name: str
    V: int  # vertices
    R: int  # relations
    E: int  # padded edges
    d: int  # original embedding dim
    D: int  # hyperspace dim
    B: int  # batch size

    # Pallas block shapes (see kernels/*.py)
    block_v: int = 128  # vertex tile
    block_do: int = 128  # hyperspace (output) tile
    block_e: int = 256  # edge tile
    block_b: int = 16  # batch tile for the score kernel

    def validate(self) -> None:
        assert self.V % self.block_v == 0, (self.name, "V % block_v")
        assert self.D % self.block_do == 0, (self.name, "D % block_do")
        assert self.E % self.block_e == 0, (self.name, "E % block_e")
        assert self.B % self.block_b == 0, (self.name, "B % block_b")

    def to_dict(self) -> dict:
        return asdict(self)


# `tiny` is the CI/pytest workhorse; `small` is the quickstart training
# preset; `fb15k_mini` approaches the paper's FB15K-237 shape scaled to fit
# CPU-PJRT runs (d=96, D=256 match Table 5's accelerator configuration).
PRESETS: dict[str, Preset] = {
    p.name: p
    for p in [
        Preset(name="tiny", V=256, R=8, E=1024, d=32, D=128, B=32,
               block_v=64, block_do=64, block_e=128, block_b=8),
        Preset(name="small", V=2048, R=32, E=8192, d=64, D=256, B=64),
        Preset(name="fb15k_mini", V=4096, R=240, E=16384, d=96, D=256, B=128),
    ]
}


def get(name: str) -> Preset:
    p = PRESETS[name]
    p.validate()
    return p
