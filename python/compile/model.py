"""L2: the HDReason model compute graph (paper §3), written in JAX on top of
the Pallas kernels in ``kernels/``. This module is build-time only: aot.py
lowers the functions below to HLO text once, and the rust coordinator
executes the compiled artifacts via PJRT forever after.

Dataflow (Fig. 2(b)):

    e^v, e^r  ──encode (Eq. 5/6, kernels.encode)──▶  H^v, H^r
    H^v, H^r, edges ──bind+aggregate (Eq. 7, kernels.bind + segment_sum)──▶ M^v
    M^v, queries ──TransE score (Eq. 10, kernels.pairwise_l1)──▶ logits
    logits, labels ──BCE──▶ loss ──jax.grad (Eq. 11/12)──▶ ∇e^v, ∇e^r

The base hypervector matrix H^B is an *input*, not a constant: it is frozen
during training (§3.2, "the base hypervector matrix remains fixed") so the
train step only returns gradients for e^v and e^r, but rust owns the H^B
buffer and feeds the same one every step.

Static shapes come from presets.py; every function here is shape-polymorphic
in Python but lowered per-preset by aot.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import bind as bind_k
from compile.kernels import encode as encode_k
from compile.kernels import score as score_k
from compile.presets import Preset


def memorize(hv, hr, src, rel, dst, mask, num_vertices: int, block_e: int):
    """Eq. 1/7: M_i = Σ_{(j,r)∈N(i)} H_j ∘ H_r, edge-list (scatter/reduce)
    formulation (§4.2.1). Gathers/scatter stay in XLA; the bind runs in the
    Pallas CU kernel."""
    bound = bind_k.bind(hv[src], hr[rel], block_e)
    bound = bound * mask[:, None]
    return jax.ops.segment_sum(bound, dst, num_segments=num_vertices)


def forward(ev, er, hb, src, rel, dst, mask, q_subj, q_rel, bias, *, p: Preset):
    """Full forward pass: (B,) queries → (B, |V|) link-prediction logits.

    The sigmoid of Eq. 10 is folded into the BCE loss during training and
    applied host-side (rust) at inference, exactly as the paper's Score
    Function IP defers the sigmoid to the CPU (Fig. 6 step 9).
    """
    hv = encode_k.encode(ev, hb, p.block_v, p.block_do)
    hr = encode_k.encode(er, hb, min(p.block_v, er.shape[0]), p.block_do)
    mv = memorize(hv, hr, src, rel, dst, mask, ev.shape[0], p.block_e)
    q = mv[q_subj] + hr[q_rel]  # object HDV (Fig. 6(b) step 1)
    dist = score_k.pairwise_l1(q, mv, p.block_b, p.block_v)
    return bias - dist


def loss_fn(ev, er, hb, src, rel, dst, mask, q_subj, q_rel, labels, bias,
            smoothing, *, p: Preset):
    logits = forward(ev, er, hb, src, rel, dst, mask, q_subj, q_rel, bias, p=p)
    # label smoothing applied unconditionally so `smoothing` can stay a
    # traced runtime scalar (identity at smoothing = 0)
    labels = labels * (1.0 - smoothing) + smoothing / labels.shape[-1]
    per = (
        jnp.maximum(logits, 0.0)
        - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
    return jnp.mean(per)


def train_step(ev, er, hb, src, rel, dst, mask, q_subj, q_rel, labels, bias,
               smoothing, *, p: Preset):
    """One training step: loss + gradients w.r.t. the original-space
    embeddings only (Eqs. 11/12 — H^B stays fixed). The optimizer update
    runs on the rust side (the paper's host-CPU embedding update, Fig. 7
    step 11)."""
    loss, (g_ev, g_er) = jax.value_and_grad(
        lambda a, b: loss_fn(a, b, hb, src, rel, dst, mask, q_subj, q_rel,
                             labels, bias, smoothing, p=p),
        argnums=(0, 1),
    )(ev, er)
    return loss, g_ev, g_er


def encode_only(ev, hb, *, p: Preset):
    """Standalone Eq. 5 artifact — used by the coordinator when the
    density-aware scheduler encodes *only* unencoded vertices (§4.2.1
    computation-reuse path)."""
    return encode_k.encode(ev, hb, min(p.block_v, ev.shape[0]), p.block_do)


def memorize_only(hv, hr, src, rel, dst, mask, *, p: Preset):
    """Standalone Eq. 7/8 artifact: aggregation given already-encoded
    hypervectors (the Dispatcher→Memorization IP path, Fig. 5)."""
    return memorize(hv, hr, src, rel, dst, mask, hv.shape[0], p.block_e)


def score_only(mv, hr, q_subj, q_rel, bias, *, p: Preset):
    """Standalone Eq. 10 artifact: the Score Function IP (Fig. 6)."""
    q = mv[q_subj] + hr[q_rel]
    return bias - score_k.pairwise_l1(q, mv, p.block_b, p.block_v)


def example_args(p: Preset):
    """ShapeDtypeStructs for lowering each artifact of preset ``p``."""
    f32 = jnp.float32
    i32 = jnp.int32
    s = jax.ShapeDtypeStruct
    return {
        "ev": s((p.V, p.d), f32),
        "er": s((p.R, p.d), f32),
        "hb": s((p.d, p.D), f32),
        "hv": s((p.V, p.D), f32),
        "hr": s((p.R, p.D), f32),
        "mv": s((p.V, p.D), f32),
        "src": s((p.E,), i32),
        "rel": s((p.E,), i32),
        "dst": s((p.E,), i32),
        "mask": s((p.E,), f32),
        "q_subj": s((p.B,), i32),
        "q_rel": s((p.B,), i32),
        "labels": s((p.B, p.V), f32),
        "bias": s((), f32),
        "smoothing": s((), f32),
    }
