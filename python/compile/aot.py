"""AOT exporter: lower every HDReason artifact to HLO *text* + manifest.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts [--presets tiny,small]

Outputs, per preset <p>:
    artifacts/forward_<p>.hlo.txt      full fwd: embeddings → (B,V) logits
    artifacts/train_step_<p>.hlo.txt   fwd+bwd: → (loss, ∇e^v, ∇e^r)
    artifacts/encode_<p>.hlo.txt       Eq. 5 standalone
    artifacts/memorize_<p>.hlo.txt     Eq. 7 standalone
    artifacts/score_<p>.hlo.txt        Eq. 10 standalone
    artifacts/manifest.json            shapes/dtypes/arg-order per artifact

Every artifact is lowered with return_tuple=True, so the rust side unwraps
with to_tuple{1,3}(). Python never runs on the request path: `make
artifacts` is the only invocation.
"""

import argparse
import functools
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model
from compile.presets import PRESETS, Preset, get


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(args_list):
    return [
        {"shape": list(a.shape), "dtype": str(a.dtype)} for a in args_list
    ]


def artifact_defs(p: Preset):
    """(name, fn, ordered example args, output arity) per artifact."""
    a = model.example_args(p)
    fwd_args = [a[k] for k in
                ("ev", "er", "hb", "src", "rel", "dst", "mask", "q_subj",
                 "q_rel", "bias")]
    ts_args = [a[k] for k in
               ("ev", "er", "hb", "src", "rel", "dst", "mask", "q_subj",
                "q_rel", "labels", "bias", "smoothing")]
    enc_args = [a["ev"], a["hb"]]
    mem_args = [a[k] for k in ("hv", "hr", "src", "rel", "dst", "mask")]
    sc_args = [a[k] for k in ("mv", "hr", "q_subj", "q_rel", "bias")]
    return [
        ("forward", lambda *xs: (model.forward(*xs, p=p),), fwd_args, 1),
        ("train_step", lambda *xs: model.train_step(*xs, p=p), ts_args, 3),
        ("encode", lambda *xs: (model.encode_only(*xs, p=p),), enc_args, 1),
        ("memorize", lambda *xs: (model.memorize_only(*xs, p=p),), mem_args, 1),
        ("score", lambda *xs: (model.score_only(*xs, p=p),), sc_args, 1),
    ]


def export_preset(p: Preset, out_dir: str) -> list[dict]:
    entries = []
    for name, fn, args, arity in artifact_defs(p):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}_{p.name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        entries.append(
            {
                "artifact": name,
                "preset": p.name,
                "file": fname,
                "inputs": _spec(args),
                "num_outputs": arity,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "config": p.to_dict(),
            }
        )
        print(f"  {fname}: {len(text)} chars")
    return entries


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts")
    parser.add_argument(
        "--presets", default=",".join(PRESETS), help="comma-separated preset names"
    )
    ns = parser.parse_args()
    os.makedirs(ns.out, exist_ok=True)
    manifest = {"format": "hlo-text", "jax": jax.__version__, "artifacts": []}
    for pname in ns.presets.split(","):
        p = get(pname.strip())
        print(f"preset {p.name}: V={p.V} R={p.R} E={p.E} d={p.d} D={p.D} B={p.B}")
        manifest["artifacts"].extend(export_preset(p, ns.out))
    with open(os.path.join(ns.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest to {ns.out}")


if __name__ == "__main__":
    main()
