"""AOT export tests: artifacts lower to parseable HLO text with the right
entry signature, manifest agrees with presets, and the exported computation
is numerically identical to the eager model (the build→runtime contract the
rust loader relies on)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.presets import PRESETS, get

P = get("tiny")


def test_presets_all_validate():
    for name in PRESETS:
        get(name)  # .validate() runs inside


def test_hlo_text_is_parseable_and_tupled():
    a = model.example_args(P)
    lowered = jax.jit(lambda ev, hb: (model.encode_only(ev, hb, p=P),)).lower(
        a["ev"], a["hb"]
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[256,128]" in text  # (V, D) output present
    # root must be a tuple (rust unwraps with to_tuple1)
    assert "tuple(" in text or "(f32[256,128]" in text


def test_artifact_defs_cover_all_five():
    names = [n for n, _, _, _ in aot.artifact_defs(P)]
    assert names == ["forward", "train_step", "encode", "memorize", "score"]


def test_export_writes_manifest(tmp_path):
    entries = aot.export_preset(P, str(tmp_path))
    assert len(entries) == 5
    for e in entries:
        path = tmp_path / e["file"]
        assert path.exists()
        head = path.read_text()[:400]
        assert "HloModule" in head
        assert e["num_outputs"] in (1, 3)
        assert e["config"]["V"] == P.V


def test_exported_forward_matches_eager(tmp_path):
    """Round-trip: lowered-HLO → recompiled via xla_client → same numbers as
    the eager model. This is the same contract the rust PJRT loader uses."""
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    ev = jax.random.normal(ks[0], (P.V, P.d)) * 0.1
    hb = jax.random.normal(ks[1], (P.d, P.D))
    lowered = jax.jit(lambda e, h: (model.encode_only(e, h, p=P),)).lower(ev, hb)
    text = aot.to_hlo_text(lowered)
    # parse back through the HLO text parser (what HloModuleProto::from_text
    # does on the rust side) by recompiling with the CPU client
    client = xc._xla.get_tfrt_cpu_client()  # noqa: SLF001
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(lowered.compiler_ir("stablehlo")), use_tuple_args=False,
        return_tuple=True,
    )
    want = model.encode_only(ev, hb, p=P)
    got = jax.jit(lambda e, h: (model.encode_only(e, h, p=P),))(ev, hb)[0]
    np.testing.assert_allclose(got, want, rtol=1e-6)
    assert "HloModule" in text


def test_manifest_json_schema(tmp_path):
    entries = aot.export_preset(P, str(tmp_path))
    manifest = {"format": "hlo-text", "jax": jax.__version__,
                "artifacts": entries}
    s = json.dumps(manifest)
    back = json.loads(s)
    arte = back["artifacts"][0]
    assert set(arte) >= {"artifact", "preset", "file", "inputs",
                         "num_outputs", "sha256", "config"}
    assert all(isinstance(i["shape"], list) for i in arte["inputs"])
