"""Kernel-vs-oracle correctness: every Pallas kernel (encode / bind /
pairwise_l1) against the pure-jnp reference, forward AND custom-VJP
backward, across hypothesis-driven shape sweeps.

This is the CORE correctness signal for the L1 layer: the same kernels are
what aot.py lowers into the artifacts the rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bind as bind_k
from compile.kernels import encode as encode_k
from compile.kernels import ref
from compile.kernels import score as score_k

SETTINGS = dict(max_examples=12, deadline=None)


def _rand(seed, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


# ---------------------------------------------------------------- encode --
@settings(**SETTINGS)
@given(
    v=st.sampled_from([16, 48, 64, 96, 128]),
    d=st.sampled_from([8, 32, 96]),
    dd=st.sampled_from([64, 128, 256]),
    bv=st.sampled_from([16, 64, 128]),
    bd=st.sampled_from([64, 128]),
)
def test_encode_matches_ref(v, d, dd, bv, bd):
    e = _rand(0, (v, d))
    hb = _rand(1, (d, dd))
    got = encode_k.encode(e, hb, bv, bd)
    np.testing.assert_allclose(got, ref.encode(e, hb), rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(v=st.sampled_from([32, 64]), d=st.sampled_from([16, 32]),
       dd=st.sampled_from([64, 128]))
def test_encode_grad_matches_ref(v, d, dd):
    e = _rand(2, (v, d))
    hb = _rand(3, (d, dd))
    w = _rand(4, (v, dd))  # random cotangent
    ge, ghb = jax.grad(
        lambda a, b: jnp.sum(encode_k.encode(a, b, 32, 64) * w), argnums=(0, 1)
    )(e, hb)
    ger, ghbr = jax.grad(
        lambda a, b: jnp.sum(ref.encode(a, b) * w), argnums=(0, 1)
    )(e, hb)
    np.testing.assert_allclose(ge, ger, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(ghb, ghbr, rtol=1e-4, atol=1e-4)


def test_encode_ragged_relation_dim():
    # |R| = 240 (fb15k_mini) does not divide the default 128 block; the
    # _fit_block divisor search must handle it
    e = _rand(5, (240, 96))
    hb = _rand(6, (96, 256))
    got = encode_k.encode(e, hb, 128, 128)
    np.testing.assert_allclose(got, ref.encode(e, hb), rtol=1e-5, atol=1e-5)


def test_encode_output_range():
    # tanh kernel ⇒ hypervectors live in (-1, 1): the HDC holographic range
    h = encode_k.encode(_rand(7, (64, 32), 10.0), _rand(8, (32, 128)), 32, 64)
    assert float(jnp.max(jnp.abs(h))) <= 1.0


# ------------------------------------------------------------------ bind --
@settings(**SETTINGS)
@given(e=st.sampled_from([64, 128, 256, 512]), d=st.sampled_from([32, 128, 256]),
       be=st.sampled_from([64, 256]))
def test_bind_matches_ref(e, d, be):
    a, b = _rand(9, (e, d)), _rand(10, (e, d))
    np.testing.assert_allclose(bind_k.bind(a, b, be), ref.bind(a, b), rtol=1e-6)


def test_bind_grad_is_operand_swap():
    a, b = _rand(11, (128, 64)), _rand(12, (128, 64))
    w = _rand(13, (128, 64))
    ga, gb = jax.grad(
        lambda x, y: jnp.sum(bind_k.bind(x, y, 64) * w), argnums=(0, 1)
    )(a, b)
    np.testing.assert_allclose(ga, w * b, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gb, w * a, rtol=1e-5, atol=1e-6)


def test_bind_self_inverse():
    # binding with ±1 hypervectors is self-inverse: a ∘ s ∘ s = a — the HDC
    # property that lets memorization be *queried* (paper §2.1)
    a = _rand(14, (64, 128))
    s = jnp.sign(_rand(15, (64, 128)))
    np.testing.assert_allclose(
        bind_k.bind(bind_k.bind(a, s, 64), s, 64), a, rtol=1e-5, atol=1e-6
    )


# ----------------------------------------------------------------- score --
@settings(**SETTINGS)
@given(b=st.sampled_from([8, 16, 32]), v=st.sampled_from([32, 96, 128]),
       d=st.sampled_from([32, 128]), bb=st.sampled_from([8, 16]),
       bv=st.sampled_from([32, 128]))
def test_pairwise_l1_matches_ref(b, v, d, bb, bv):
    q, m = _rand(16, (b, d)), _rand(17, (v, d))
    got = score_k.pairwise_l1(q, m, bb, bv)
    np.testing.assert_allclose(got, ref.pairwise_l1(q, m), rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(b=st.sampled_from([8, 16]), v=st.sampled_from([32, 64]),
       d=st.sampled_from([32, 64]))
def test_pairwise_l1_grads_match_ref(b, v, d):
    q, m = _rand(18, (b, d)), _rand(19, (v, d))
    w = _rand(20, (b, v))
    gq, gm = jax.grad(
        lambda a, c: jnp.sum(score_k.pairwise_l1(a, c, 8, 32) * w), argnums=(0, 1)
    )(q, m)
    gqr, gmr = jax.grad(
        lambda a, c: jnp.sum(ref.pairwise_l1(a, c) * w), argnums=(0, 1)
    )(q, m)
    np.testing.assert_allclose(gq, gqr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gm, gmr, rtol=1e-4, atol=1e-4)


def test_pairwise_l1_zero_distance_diagonal():
    m = _rand(21, (32, 64))
    d = score_k.pairwise_l1(m[:8], m, 8, 32)
    # row b equals vertex b ⇒ distance 0 on the diagonal, > 0 elsewhere
    np.testing.assert_allclose(jnp.diagonal(d[:, :8]), jnp.zeros(8), atol=1e-6)
    assert float(jnp.min(d + jnp.eye(8, 32) * 1e9)) > 0.0


def test_pairwise_l1_triangle_inequality():
    # L1 metric property: d(q, m) ≤ d(q, x) + d(x, m) for the same x
    q, m, x = _rand(22, (4, 32)), _rand(23, (16, 32)), _rand(24, (1, 32))
    dqm = score_k.pairwise_l1(q, m, 4, 16)
    dqx = score_k.pairwise_l1(q, x, 4, 1)
    dxm = score_k.pairwise_l1(jnp.broadcast_to(x, (4, 32)), m, 4, 16)
    assert bool(jnp.all(dqm <= dqx + dxm + 1e-4))
