"""L2 model-level tests: shapes, oracle equivalence of the full forward and
train_step, dense-vs-edge-list memorization equivalence (Eq. 7 ≡ Eq. 8), and
training-dynamics sanity (loss decreases under SGD on a learnable toy KG)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref
from compile.presets import get

P = get("tiny")


def _graph(seed=0, live_edges=900):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    ev = jax.random.normal(ks[0], (P.V, P.d)) * 0.1
    er = jax.random.normal(ks[1], (P.R, P.d)) * 0.1
    hb = jax.random.normal(ks[2], (P.d, P.D))
    src = jax.random.randint(ks[3], (P.E,), 0, P.V).astype(jnp.int32)
    rel = jax.random.randint(ks[4], (P.E,), 0, P.R).astype(jnp.int32)
    dst = jax.random.randint(ks[5], (P.E,), 0, P.V).astype(jnp.int32)
    mask = (jnp.arange(P.E) < live_edges).astype(jnp.float32)
    qs = jax.random.randint(ks[6], (P.B,), 0, P.V).astype(jnp.int32)
    qr = jax.random.randint(ks[7], (P.B,), 0, P.R).astype(jnp.int32)
    labels = jnp.zeros((P.B, P.V)).at[jnp.arange(P.B), dst[: P.B]].set(1.0)
    return ev, er, hb, src, rel, dst, mask, qs, qr, labels


def test_forward_shape_and_ref():
    ev, er, hb, src, rel, dst, mask, qs, qr, _ = _graph()
    logits = model.forward(ev, er, hb, src, rel, dst, mask, qs, qr,
                           jnp.float32(0.0), p=P)
    assert logits.shape == (P.B, P.V)
    want = ref.forward(ev, er, hb, src, rel, dst, mask, qs, qr, 0.0)
    np.testing.assert_allclose(logits, want, rtol=1e-3, atol=1e-3)


def test_train_step_matches_ref_grads():
    ev, er, hb, src, rel, dst, mask, qs, qr, labels = _graph(1)
    loss, gv, gr = model.train_step(ev, er, hb, src, rel, dst, mask, qs, qr,
                                    labels, jnp.float32(0.0), jnp.float32(0.1),
                                    p=P)
    lref, (gvr, grr) = jax.value_and_grad(
        lambda a, b: ref.bce_loss(
            ref.forward(a, b, hb, src, rel, dst, mask, qs, qr, 0.0), labels, 0.1
        ),
        argnums=(0, 1),
    )(ev, er)
    np.testing.assert_allclose(float(loss), float(lref), rtol=1e-4)
    np.testing.assert_allclose(gv, gvr, rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(gr, grr, rtol=1e-3, atol=1e-5)


def test_memorize_edge_list_equals_dense():
    """Eq. 7 (scatter/reduce, what the hardware runs) ≡ Eq. 8 (Σ_r A_r H ∘ E_r,
    the paper's matrix form) on a small dense-representable graph."""
    V, R, D = 24, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    hv = jax.random.normal(ks[0], (V, D))
    hr = jax.random.normal(ks[1], (R, D))
    E = 64
    src = jax.random.randint(ks[2], (E,), 0, V).astype(jnp.int32)
    rel = jax.random.randint(ks[3], (E,), 0, R).astype(jnp.int32)
    dst = (src * 7 + 3) % V
    # dedupe (dense adjacency is 0/1; repeated triples would double-count)
    seen, keep = set(), []
    for i in range(E):
        t = (int(src[i]), int(rel[i]), int(dst[i]))
        keep.append(t not in seen)
        seen.add(t)
    mask = jnp.array(keep, dtype=jnp.float32)
    adj = jnp.zeros((R, V, V))
    for i in range(E):
        if keep[i]:
            adj = adj.at[int(rel[i]), int(dst[i]), int(src[i])].set(1.0)
    got = ref.memorize_edges(hv, hr, src, rel, dst, mask, V)
    want = ref.memorize_dense(hv, hr, adj)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_padded_edges_do_not_contribute():
    ev, er, hb, src, rel, dst, mask, qs, qr, _ = _graph(2, live_edges=500)
    base = model.forward(ev, er, hb, src, rel, dst, mask, qs, qr,
                         jnp.float32(0.0), p=P)
    # scramble the masked-out tail: output must not change
    src2 = src.at[500:].set((src[500:] + 17) % P.V)
    dst2 = dst.at[500:].set((dst[500:] + 5) % P.V)
    out = model.forward(ev, er, hb, src2, rel, dst2, mask, qs, qr,
                        jnp.float32(0.0), p=P)
    np.testing.assert_allclose(base, out, rtol=1e-5, atol=1e-5)


def test_loss_decreases_under_sgd():
    ev, er, hb, src, rel, dst, mask, qs, qr, labels = _graph(3)
    lr = 0.5
    losses = []
    for _ in range(6):
        loss, gv, gr = model.train_step(ev, er, hb, src, rel, dst, mask, qs,
                                        qr, labels, jnp.float32(0.0),
                                        jnp.float32(0.0), p=P)
        losses.append(float(loss))
        ev = ev - lr * gv
        er = er - lr * gr
    assert losses[-1] < losses[0], losses
    assert all(np.isfinite(losses)), losses


def test_bias_shifts_logits_uniformly():
    ev, er, hb, src, rel, dst, mask, qs, qr, _ = _graph(4)
    l0 = model.forward(ev, er, hb, src, rel, dst, mask, qs, qr,
                       jnp.float32(0.0), p=P)
    l1 = model.forward(ev, er, hb, src, rel, dst, mask, qs, qr,
                       jnp.float32(2.5), p=P)
    np.testing.assert_allclose(l1 - l0, jnp.full_like(l0, 2.5), rtol=1e-5)
