import jax
import pytest


@pytest.fixture(scope="session", autouse=True)
def _jax_x64_off():
    # artifacts are f32; keep tests on the same numerics
    jax.config.update("jax_enable_x64", False)
    yield


def rand(key, shape, scale=1.0):
    import jax.random as jr

    return jr.normal(jr.PRNGKey(key), shape) * scale
