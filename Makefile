# HDReason repo targets. Tier-1 verify is `make check`.
#
# The rust crate lives under rust/; everything here drives it via
# --manifest-path so the targets work from the repo root.

CARGO ?= cargo
MANIFEST := rust/Cargo.toml

.PHONY: check build test bench bench-serving bench-train ci fmt artifacts lint analyze loom miri tsan

# tier-1: release build + full test suite
check: build test

build:
	$(CARGO) build --release --manifest-path $(MANIFEST)

test:
	$(CARGO) test -q --manifest-path $(MANIFEST)

# what .github/workflows/ci.yml runs — keep the two in lock-step.
# The HDR_THREADS matrix pins the kernel layer's auto-threading to explicit
# worker counts so shard/batcher races can't hide behind a single-core (or
# many-core) runner; the default run keeps auto-threading covered too.
ci:
	$(CARGO) fmt --check --manifest-path $(MANIFEST)
	$(CARGO) clippy --manifest-path $(MANIFEST) --all-targets -- -D warnings
	$(CARGO) clippy --manifest-path $(MANIFEST) -p xtask --all-targets -- -D warnings
	$(CARGO) test -q --manifest-path $(MANIFEST) -p xtask
	$(MAKE) lint
	$(MAKE) analyze
	$(CARGO) build --release --manifest-path $(MANIFEST)
	$(CARGO) test -q --manifest-path $(MANIFEST)
	HDR_THREADS=1 $(CARGO) test -q --manifest-path $(MANIFEST)
	HDR_THREADS=2 $(CARGO) test -q --manifest-path $(MANIFEST)
	$(CARGO) run --release --manifest-path $(MANIFEST) -- query --model tiny --queries 64 --backend sharded:2+quant:8
	$(CARGO) run --release --manifest-path $(MANIFEST) -- query --model tiny --queries 64 --backend noisy:gauss:0.1:42+sharded:2+quant:8
	$(CARGO) run --release --manifest-path $(MANIFEST) -- train --model tiny --runtime host --epochs 3 --steps 8 --eval-every 3
	$(CARGO) test -q --release --manifest-path $(MANIFEST) --test noise_robustness -- degrades
	$(CARGO) run --release --manifest-path $(MANIFEST) -- serve --model tiny --duration-ms 400 --ops 512 --clients 2 --mutate-batch 8 --backend noisy:gauss:0.05:42+sharded:2+quant:8
	$(CARGO) run --release --manifest-path $(MANIFEST) -- query --model tiny --queries 256 --backend sharded:2+quant:8 --cache lfu:256 --min-hit-rate 0.25
	$(CARGO) run --release --manifest-path $(MANIFEST) -- serve --model tiny --duration-ms 1500 --ops 1024 --clients 2 --mutate-batch 8 --mutate-pause-us 20000 --backend noisy:gauss:0.05:42+sharded:2+quant:8 --cache lfu:256 --min-hit-rate 0.003

# hot-path + serving benchmarks; append {name, median_s, iters} JSON-lines
# rows to BENCH_8.json at the repo root so the perf trajectory accumulates
# per PR (the serving run carries the noisy fault-channel overhead rows,
# the live-mutation churn section, and the Zipf serving-cache policy rows)
bench:
	$(CARGO) bench --bench runtime_hotpath --manifest-path $(MANIFEST) -- --json
	$(CARGO) bench --bench engine_serving --manifest-path $(MANIFEST) -- --json

# KgcEngine serving throughput: submit at batch 1/8/64, sharded/quant
# score backends, the submit_async pipeline, the rank-native
# (rank-only / top-k) sharded rows, the noisy fault-channel overhead
# rows, the live-mutation churn rows — incremental delta vs full
# rebuild, q/s + p50/p99 under concurrent mutation — and the Zipf
# serving-cache policy comparison (q/s + hit-rate rows per policy, same
# BENCH_8.json sink)
bench-serving:
	$(CARGO) bench --bench engine_serving --manifest-path $(MANIFEST) -- --json

# host-native training throughput: train_step steps/sec at 1 thread vs
# max (target >= 2x), quant/sharded training backends (same BENCH_8.json
# sink)
bench-train:
	$(CARGO) bench --bench train_throughput --manifest-path $(MANIFEST) -- --json

fmt:
	$(CARGO) fmt --manifest-path $(MANIFEST)

# the concurrency lint pass (see CONCURRENCY.md and rust/xtask/src/main.rs):
# std::sync outside the sync shim, .lock().unwrap(), hash iteration in the
# score hot paths, out-of-order LockRank acquisition. Offline and std-only.
lint:
	$(CARGO) run --quiet --manifest-path $(MANIFEST) -p xtask -- lint

# whole-crate static analysis (see ANALYSIS.md): HDR-PANIC (no panics
# reachable from the serving entry points), HDR-ALLOC (no allocation in
# #[hdr_hot_path] kernels), HDR-FLOAT (no order-sensitive reductions
# outside the blocked helpers), HDR-EPOCH (epoch-disciplined cache writes
# and snapshot reads). Offline and std-only, like the lint pass.
analyze:
	$(CARGO) run --quiet --manifest-path $(MANIFEST) -p xtask -- analyze

# exhaustive model checks over the serving protocols: --cfg loom swaps
# hdreason::sync to the in-crate model checker (rust/src/sync/model.rs)
# and compiles tests/loom_models.rs non-empty
loom:
	RUSTFLAGS="--cfg loom" $(CARGO) test -q --manifest-path $(MANIFEST) --test loom_models

# nightly-only sanitizers — not part of `make ci` (the offline gate runs
# on stable); CI runs them as separate jobs. Miri interprets the lib unit
# tests (the protocol + sync layers); isolation is off because the
# protocol tests read Instant::now.
miri:
	MIRIFLAGS="-Zmiri-disable-isolation" \
		$(CARGO) +nightly miri test -q --manifest-path $(MANIFEST) --lib -- engine::protocol:: sync::

# ThreadSanitizer over the real engine integration tests (needs rust-src
# for -Zbuild-std so std itself is instrumented)
tsan:
	RUSTFLAGS="-Zsanitizer=thread" \
		$(CARGO) +nightly test -q --manifest-path $(MANIFEST) \
		-Zbuild-std --target x86_64-unknown-linux-gnu \
		--test engine_api --test concurrency_props

# AOT-compile the python layer to HLO-text artifacts (requires jax; only
# useful to a `--features pjrt` build — the default stub build skips the
# artifact-dependent tests/benches). rust/artifacts is where cargo-test's
# working directory resolves `Manifest::default_dir()`.
artifacts:
	cd python && python3 -m compile.aot --out ../rust/artifacts
